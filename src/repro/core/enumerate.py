"""Enumeration of HoF-nest rearrangements — paper §4.

A dense contraction (matmul, matvec, the weighted variants of eqs 1-2, 6-7)
is described by a ``ContractionSpec``: operands with named indices, output
indices (map dims), and reduced indices (rnz dims).  A *variant* is an
ordering of the loop indices (the paper's "HoF order from left to right is
the nesting from top down") plus optional subdivisions of indices.

``sjt`` enumerates orderings by adjacent transpositions
(Steinhaus–Johnson–Trotter, refs [16][17] of the paper) — each neighbouring
variant differs by exactly one application of an exchange rule from
``rules.py`` (map/map, map/rnz, or rnz/rnz), which is how the paper justifies
the walk.  ``nest_to_expr`` emits the DSL expression for a variant, with the
operand ``Subdiv``/``Flip`` prefix required by the exchange rules ("exchanging
two nested higher order functions must be done with an appropriate flip in
the subdivision structure").

The consumer that closes the paper's loop is ``repro.search``: it feeds
``variant_orders`` + per-tier subdivision choices through the analytic
cost cut (``core.cost``), lowers the survivors via ``repro.codegen``, and
measures them — see ``src/repro/search/__init__.py`` for the pipeline
diagram.  ``repro.grad.derive`` generates *new* specs from these by index
calculus (the backward contractions of training), which re-enter the same
walk/search/codegen machinery as first-class citizens.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Iterator, List, Sequence, Tuple

import numpy as np

from . import expr as E
from .expr import App, Flip, Lam, MapN, Prim, RNZ, Subdiv, Var, fresh


# ---------------------------------------------------------------------------
# Steinhaus–Johnson–Trotter
# ---------------------------------------------------------------------------


def sjt(n: int) -> Iterator[Tuple[int, ...]]:
    """All permutations of range(n) by adjacent transpositions."""
    perm = list(range(n))
    dirs = [-1] * n  # all point left initially
    yield tuple(perm)
    while True:
        # largest mobile element
        mobile_idx = -1
        for i in range(n):
            j = i + dirs[i]
            if 0 <= j < n and perm[i] > perm[j]:
                if mobile_idx == -1 or perm[i] > perm[mobile_idx]:
                    mobile_idx = i
        if mobile_idx == -1:
            return
        j = mobile_idx + dirs[mobile_idx]
        perm[mobile_idx], perm[j] = perm[j], perm[mobile_idx]
        dirs[mobile_idx], dirs[j] = dirs[j], dirs[mobile_idx]
        moved = perm[j]
        for i in range(n):
            if perm[i] > moved:
                dirs[i] = -dirs[i]
        yield tuple(perm)


# ---------------------------------------------------------------------------
# contraction specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantMeta:
    """Low-precision storage format of a contraction's operands.

    ``dtype`` is the operand storage dtype, ``accum`` the accumulator the
    generated kernel carries in VMEM (int8 products must accumulate in
    int32 to stay exact; fp8 accumulates in f32), and ``scale`` the
    granularity of the dequantization scales applied by the epilogue
    (``per_channel`` = one scale per output column, ``per_tensor`` = one
    scale broadcast over the whole output).  The scales themselves are
    runtime epilogue vectors, not spec data — the spec only records *that*
    the kernel's inputs are quantized and how to undo it.
    """

    dtype: str            # "int8" | "float8_e4m3fn"
    accum: str            # "int32" | "float32"
    scale: str = "per_channel"  # "per_channel" | "per_tensor" | "per_block"

    def __post_init__(self):
        if self.dtype not in ("int8", "float8_e4m3fn"):
            raise ValueError(f"unsupported quant dtype {self.dtype!r}")
        if self.accum not in ("int32", "float32"):
            raise ValueError(f"unsupported quant accumulator {self.accum!r}")
        if self.scale not in ("per_channel", "per_tensor", "per_block"):
            raise ValueError(f"unsupported scale granularity {self.scale!r}")


#: canonical quant formats; keys are what ``ops.dense(quant=...)``,
#: ``--quant`` and the search ladder accept
QUANT_FORMATS: Dict[str, QuantMeta] = {
    "int8": QuantMeta(dtype="int8", accum="int32"),
    "fp8": QuantMeta(dtype="float8_e4m3fn", accum="float32"),
}


@dataclasses.dataclass(frozen=True)
class ContractionSpec:
    """An einsum-like dense contraction expressed over named indices."""

    name: str
    operands: Dict[str, Tuple[str, ...]]  # operand -> indices, outermost-first
    output: Tuple[str, ...]
    extents: Dict[str, int]
    reducer: str = "+"
    #: builds the innermost scalar expr from {operand: scalar Expr}
    scalar: Callable[[Dict[str, E.Expr]], E.Expr] = None  # type: ignore
    #: subdivision provenance: this spec = parent with `split` index subdivided
    parent: "ContractionSpec" = None  # type: ignore
    split: Tuple[str, int] = None  # type: ignore
    #: low-precision storage format (``subdivide`` drops this like
    #: ``fused_kind`` — always detect via ``spec.root().quant``)
    quant: QuantMeta = None  # type: ignore

    def __post_init__(self):
        if self.scalar is None:
            object.__setattr__(self, "scalar", _product_scalar)

    @property
    def indices(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for idxs in self.operands.values():
            for i in idxs:
                if i not in seen:
                    seen.append(i)
        return tuple(seen)

    @property
    def reduce_indices(self) -> Tuple[str, ...]:
        return tuple(i for i in self.indices if i not in self.output)

    def kind(self, index: str) -> str:
        return "map" if index in self.output else "rnz"

    def flops(self) -> int:
        # one multiply-chain + one add per innermost point
        muls = max(len(self.operands) - 1, 1)
        pts = math.prod(self.extents[i] for i in self.indices)
        return pts * (muls + (1 if self.reduce_indices else 0))

    def subdivide(self, index: str, b: int) -> "ContractionSpec":
        """Split ``index`` into (index_o, index_i) blocks — the paper's subdiv."""
        e = self.extents[index]
        if e % b:
            raise ValueError(f"{b} does not divide extent {e} of {index}")
        io, ii = index + "o", index + "i"

        def expand(idxs: Tuple[str, ...]) -> Tuple[str, ...]:
            out: List[str] = []
            for i in idxs:
                out.extend((io, ii) if i == index else (i,))
            return tuple(out)

        extents = dict(self.extents)
        del extents[index]
        extents[io], extents[ii] = e // b, b
        return ContractionSpec(
            name=self.name,
            operands={k: expand(v) for k, v in self.operands.items()},
            output=expand(self.output),
            extents=extents,
            reducer=self.reducer,
            scalar=self.scalar,
            parent=self,
            split=(index, b),
        )

    def split_chain(self) -> List[Tuple[str, int]]:
        """Subdivisions applied to reach this spec, outermost application first."""
        chain: List[Tuple[str, int]] = []
        node = self
        while node.parent is not None:
            chain.append(node.split)
            node = node.parent
        return list(reversed(chain))

    def root(self) -> "ContractionSpec":
        node = self
        while node.parent is not None:
            node = node.parent
        return node


def _product_scalar(elems: Dict[str, E.Expr]) -> E.Expr:
    out = None
    for e in elems.values():
        out = e if out is None else App(Prim("*"), (out, e))
    return out


def einsum_formula(spec: ContractionSpec) -> str:
    """np/jnp einsum string for a ROOT spec, operands in spec order.

    The single home of the index-letter mapping — shared by the search
    measurement oracle (``search.measure.einsum_reference``), the grad
    einsum fallbacks (``grad.vjp``) and the test layer.
    """
    spec = spec.root()
    letters = {i: chr(ord("a") + n) for n, i in enumerate(spec.indices)}
    subs = ",".join(
        "".join(letters[i] for i in axes) for axes in spec.operands.values()
    )
    out = "".join(letters[i] for i in spec.output)
    return f"{subs}->{out}"


# canonical specs used by the paper -------------------------------------------


def quantize_spec(
    spec: ContractionSpec, fmt: str = "int8", scale: str = "per_channel"
) -> ContractionSpec:
    """Re-tag a ROOT spec as low-precision: same contraction, quant storage.

    The spec *name* stays the family name so plan keys read
    ``matmul@...@dtype=int8`` — quantization is a storage property, not a
    new contraction family.  Fused kinds (attention, grouped) have no
    quant lowering yet and are rejected loudly.
    """
    if spec.parent is not None:
        raise ValueError("quantize_spec expects a root (unsubdivided) spec")
    if getattr(spec, "fused_kind", ""):
        raise NotImplementedError(
            f"fused family {spec.fused_kind!r} has no quantized lowering"
        )
    meta = QUANT_FORMATS.get(fmt)
    if meta is None:
        raise ValueError(
            f"unknown quant format {fmt!r} (expected one of "
            f"{sorted(QUANT_FORMATS)})"
        )
    if scale != meta.scale:
        meta = dataclasses.replace(meta, scale=scale)
    return dataclasses.replace(spec, quant=meta)


def quantized_matmul_spec(
    n: int, m: int, k: int, fmt: str = "int8", scale: str = "per_channel"
) -> ContractionSpec:
    """matmul_spec with int8/fp8 operand storage and scale metadata."""
    return quantize_spec(matmul_spec(n, m, k), fmt=fmt, scale=scale)


def matmul_spec(n: int, m: int, k: int) -> ContractionSpec:
    """C_ik = sum_j A_ij B_jk (paper eq 50); B stored row-major (j,k)."""
    return ContractionSpec(
        name="matmul",
        operands={"A": ("i", "j"), "B": ("j", "k")},
        output=("i", "k"),
        extents={"i": n, "j": m, "k": k},
    )


def matvec_spec(n: int, m: int) -> ContractionSpec:
    """v_i = sum_j A_ij u_j (paper eq 38)."""
    return ContractionSpec(
        name="matvec",
        operands={"A": ("i", "j"), "u": ("j",)},
        output=("i",),
        extents={"i": n, "j": m},
    )


def weighted_matmul_spec(n: int, m: int, k: int) -> ContractionSpec:
    """C_ik = sum_j A_ij B_jk g_j (paper eq 2/6)."""
    return ContractionSpec(
        name="weighted_matmul",
        operands={"A": ("i", "j"), "B": ("j", "k"), "g": ("j",)},
        output=("i", "k"),
        extents={"i": n, "j": m, "k": k},
    )


def batched_matmul_spec(b: int, n: int, m: int, k: int) -> ContractionSpec:
    """out[b,i,k] = sum_j A[b,i,j] B[b,j,k] — the serving/attention shape."""
    return ContractionSpec(
        name="batched_matmul",
        operands={"A": ("b", "i", "j"), "B": ("b", "j", "k")},
        output=("b", "i", "k"),
        extents={"b": b, "i": n, "j": m, "k": k},
    )


def chain_matmul_spec(n: int, m: int, p: int, q: int) -> ContractionSpec:
    """out[i,l] = sum_{j,k} A[i,j] B[j,k] C[k,l] — the A@B@C chain.

    A single spec with two reduce indices: the per-block contraction is
    multilinear in each reduction block, so summing block-local
    einsum("ij,jk,kl->il") terms over (jo, ko) chunks reproduces the
    chained product exactly (no intermediate matrix is materialized in
    HBM — the paper's fusion claim applied across *two* contractions).
    """
    return ContractionSpec(
        name="chain_matmul",
        operands={"A": ("i", "j"), "B": ("j", "k"), "C": ("k", "l")},
        output=("i", "l"),
        extents={"i": n, "j": m, "k": p, "l": q},
    )


def transposed_matmul_spec(n: int, m: int, k: int) -> ContractionSpec:
    """out[i,k] = sum_j A[j,i] B[j,k] — A stored transposed (weight grads).

    This is the hand-written ancestor of the *derived* backward specs:
    ``repro.grad.derive.derived_spec(matmul_spec(...), "B")`` produces the
    same contraction shape mechanically (dB = Aᵀ·g), for any spec family.
    """
    return ContractionSpec(
        name="transposed_matmul",
        operands={"A": ("j", "i"), "B": ("j", "k")},
        output=("i", "k"),
        extents={"i": n, "j": m, "k": k},
    )


# fused kernel families ------------------------------------------------------
#
# A fused spec is still a ContractionSpec — its operands/output/extents
# drive the generic enumerate->search->plan machinery unchanged — but the
# innermost semantics are NOT a plain product-reduce: `fused_kind` names a
# dedicated Pallas lowering in ``codegen.fused_gen`` and every einsum-based
# consumer (measurement oracle, grad fallbacks) must branch on it.
# ``whole_indices`` are axes the fused kernel keeps unblocked (attention's
# head dims; grouped's group/contraction axes) — the search space pins them.
# NOTE: ``subdivide`` returns a plain ContractionSpec, so fused detection
# must always go through ``getattr(spec.root(), "fused_kind", "")``.


@dataclasses.dataclass(frozen=True)
class AttentionSpec(ContractionSpec):
    """Fused QK^T -> online-softmax -> PV attention.

    out[h,s,e] = sum_t softmax_t(Q[h,s,:]·K[h,t,:] / sqrt(d) + mask) V[h,t,e]

    The KV sequence axis ``t`` is the in-schedule reduction tier: the
    generated kernel walks its blocks sequentially carrying running
    max/sum state in VMEM (flash-attention style), so ``t`` is a legal
    seq-tier chunk axis while ``d``/``e`` stay whole.
    """

    causal: bool = False

    fused_kind = "attention"
    whole_indices = ("d", "e")

    def flops(self) -> int:
        h, s, t = self.extents["h"], self.extents["s"], self.extents["t"]
        d, e = self.extents["d"], self.extents["e"]
        # two GEMMs plus the softmax exp/rescale work per score
        return 2 * h * s * t * d + 2 * h * s * t * e + 4 * h * s * t

    def fused_meta(self) -> Dict[str, object]:
        return {"causal": bool(self.causal)}


@dataclasses.dataclass(frozen=True)
class GroupedSpec(ContractionSpec):
    """Ragged grouped matmul — MoE expert dispatch as ONE contraction.

    out[n,f] = x[n,:] @ w[group(n),:,:] where rows are partitioned into
    ``len(group_sizes)`` contiguous groups (sum(group_sizes) == extent of
    ``n``).  Lowered as a group-offset Pallas grid; groups may be empty.
    """

    group_sizes: Tuple[int, ...] = ()

    fused_kind = "grouped_matmul"
    whole_indices = ("g", "k")

    @property
    def indices(self) -> Tuple[str, ...]:
        # the derived dW spec has `g` only in its OUTPUT (the group axis
        # of a ragged contraction maps rows to slabs via group_sizes, not
        # via an operand index), so output axes join the index set here
        seen = list(super().indices)
        for i in self.output:
            if i not in seen:
                seen.append(i)
        return tuple(seen)

    def flops(self) -> int:
        k = self.extents["k"]
        f = self.extents["f"]
        return sum(2 * s * k * f for s in self.group_sizes)

    def fused_meta(self) -> Dict[str, object]:
        return {"group_sizes": list(self.group_sizes)}


def attention_spec(
    h: int, s: int, t: int, d: int, e: int = None, causal: bool = False
) -> AttentionSpec:
    """Fused attention over folded heads: Q(h,s,d) K(h,t,d) V(h,t,e)."""
    if e is None:
        e = d
    return AttentionSpec(
        name="attention",
        operands={"Q": ("h", "s", "d"), "K": ("h", "t", "d"), "V": ("h", "t", "e")},
        output=("h", "s", "e"),
        extents={"h": h, "s": s, "t": t, "d": d, "e": e},
        causal=causal,
    )


def grouped_matmul_spec(
    group_sizes: Sequence[int], k: int, f: int
) -> GroupedSpec:
    """Ragged per-group GEMM: x(n,k) w(g,k,f) -> out(n,f), n = sum(groups)."""
    sizes = tuple(int(s) for s in group_sizes)
    if any(s < 0 for s in sizes) or not sizes:
        raise ValueError(f"bad group_sizes {sizes}")
    return GroupedSpec(
        name="grouped_matmul",
        operands={"X": ("n", "k"), "W": ("g", "k", "f")},
        output=("n", "f"),
        extents={"n": max(sum(sizes), 1), "k": k, "f": f, "g": len(sizes)},
        group_sizes=sizes,
    )


def uniform_grouped_spec(g: int, m: int, k: int, f: int) -> GroupedSpec:
    """CLI-friendly grouped ctor: g uniform groups of m rows each."""
    return grouped_matmul_spec((m,) * g, k, f)


def tensor_contraction_spec(n: int, m: int, k: int, p: int, q: int) -> ContractionSpec:
    """C_ipq = sum_jk A_ijk B_jp C_kq g_j f_k (paper eq 7, PDE-style)."""
    return ContractionSpec(
        name="pde_contraction",
        operands={
            "A": ("i", "j", "k"),
            "B": ("j", "p"),
            "C": ("k", "q"),
            "g": ("j",),
            "f": ("k",),
        },
        output=("i", "p", "q"),
        extents={"i": n, "j": m, "k": k, "p": p, "q": q},
    )


# ---------------------------------------------------------------------------
# variant -> DSL expression
# ---------------------------------------------------------------------------


def _operand_expr(
    spec: ContractionSpec, name: str, order: Sequence[str]
) -> Tuple[E.Expr, Tuple[str, ...]]:
    """Wrap Var(name) in the Subdiv/Flip prefix required by variant ``order``.

    The actual input array is the *root* (unsubdivided) operand; this emits
    the paper's subdiv ops to realize every split that touches this operand,
    then Flips to sort its axes into loop-order (outermost first).
    Returns (expr, final axis order).
    """
    axes = list(spec.root().operands[name])
    e: E.Expr = Var(name)
    for index, b in spec.split_chain():
        if index not in axes:
            continue
        p = axes.index(index)  # outermost-first position
        d = len(axes) - 1 - p  # innermost-first dim
        e = Subdiv(d, b, e)
        axes[p : p + 1] = [index + "o", index + "i"]
    assert tuple(sorted(axes, key=order.index)) == tuple(
        sorted(spec.operands[name], key=order.index)
    )
    idxs = tuple(axes)
    target = tuple(sorted(idxs, key=order.index))
    rank = len(axes)
    # selection sort, emitting a Flip per swap (dims innermost-first)
    for pos in range(rank):
        want = target[pos]
        cur = axes.index(want)
        if cur != pos:
            d1 = rank - 1 - pos
            d2 = rank - 1 - cur
            e = Flip(min(d1, d2), max(d1, d2), e)
            axes[pos], axes[cur] = axes[cur], axes[pos]
    return e, target


def lift_n(r: E.Expr, n: int) -> E.Expr:
    for _ in range(n):
        r = E.lift(r)
    return r


def nest_to_expr(spec: ContractionSpec, order: Sequence[str]) -> E.Expr:
    """Build the DSL expression for loop ordering ``order`` (outer -> inner)."""
    assert set(order) == set(spec.indices), (order, spec.indices)

    # live operand expressions + their remaining axis lists
    live: Dict[str, E.Expr] = {}
    remaining: Dict[str, List[str]] = {}
    for name in spec.operands:
        expr_, axes = _operand_expr(spec, name, order)
        live[name] = expr_
        remaining[name] = list(axes)

    def build(k: int) -> E.Expr:
        if k == len(order):
            return spec.scalar({n: live[n] for n in spec.operands})
        idx = order[k]
        involved = [n for n in spec.operands if remaining[n] and remaining[n][0] == idx]
        if not involved:
            return build(k + 1)
        params, saved = [], {}
        for n in involved:
            p = fresh(n.lower())
            params.append(p)
            saved[n] = (live[n], remaining[n])
            live[n] = Var(p)
            remaining[n] = remaining[n][1:]
        body = build(k + 1)
        args = tuple(saved[n][0] for n in involved)
        if spec.kind(idx) == "map":
            out: E.Expr = MapN(Lam(tuple(params), body), args)
        else:
            maps_below = sum(
                1 for j in order[k + 1 :] if spec.kind(j) == "map"
            )
            reducer = lift_n(Prim(spec.reducer), maps_below)
            out = RNZ(reducer, Lam(tuple(params), body), args)
        for n in involved:
            live[n], remaining[n] = saved[n]
        return out

    return build(0)


def output_axis_order(spec: ContractionSpec, order: Sequence[str]) -> Tuple[str, ...]:
    """Axis order (outermost-first) of the result produced by nest_to_expr."""
    return tuple(i for i in order if spec.kind(i) == "map")


def evaluate_variant(
    spec: ContractionSpec, order: Sequence[str], arrays: Dict[str, np.ndarray]
) -> np.ndarray:
    """Interpret the variant and canonicalize the output to spec.output order."""
    from .interp import run

    out = np.asarray(run(nest_to_expr(spec, order), **arrays))
    produced = output_axis_order(spec, order)
    perm = tuple(produced.index(i) for i in spec.output)
    out = np.transpose(out, perm)
    # merge split output axes back (outer,inner are adjacent in spec.output)
    root_shape = tuple(
        spec.root().extents[i] for i in spec.root().output
    )
    return out.reshape(root_shape)


def variant_orders(
    spec: ContractionSpec, dedup_rnz: bool = True
) -> List[Tuple[str, ...]]:
    """All loop orderings via SJT.

    ``dedup_rnz`` treats equal-reducer rnz dims of the *same split index
    chain* order-insensitively only when adjacent blocks — the paper keeps
    12 cases for the subdivided matmul because the two rnzs are
    indistinguishable; we dedup orders that differ only by relabeling of
    split siblings at the same nesting relation (jo must stay outside ji).
    """
    idxs = spec.indices
    seen = set()
    out: List[Tuple[str, ...]] = []
    for perm in sjt(len(idxs)):
        order = tuple(idxs[p] for p in perm)
        # block-split sanity: an outer split index must nest outside its inner
        ok = True
        for i in idxs:
            if i.endswith("o") and i[:-1] + "i" in idxs:
                if order.index(i) > order.index(i[:-1] + "i"):
                    ok = False
                    break
        if not ok:
            continue
        key = order
        if dedup_rnz:
            # canonical label: positions of rnz dims as a multiset pattern
            key = tuple(
                ("R" if spec.kind(i) == "rnz" else i) for i in order
            )
            # distinguish which operands each rnz index touches
            key = tuple(
                (
                    k
                    if k != "R"
                    else "R:" + ",".join(sorted(
                        n for n, ax in spec.operands.items() if order[pos] in ax
                    ))
                )
                for pos, k in enumerate(key)
            )
        if key in seen:
            continue
        seen.add(key)
        out.append(order)
    return out


# ---------------------------------------------------------------------------
# rule-driven derivation (the Fig-3 six matvec forms)
# ---------------------------------------------------------------------------


def paper_fig3_variants(n: int, m: int, b: int):
    """The six matvec rearrangements of paper Fig 3, as (label, order, spec).

    1a/1b/1c subdivide the reduction (vector) index j; 2a/2b/2c subdivide the
    map index i.  Orders are the nestings shown in the figure.
    """
    base = matvec_spec(n, m)
    s1 = base.subdivide("j", b)  # jo, ji
    s2 = base.subdivide("i", b)  # io, ii
    return [
        ("1a", ("i", "jo", "ji"), s1),
        ("1b", ("jo", "i", "ji"), s1),
        ("1c", ("jo", "ji", "i"), s1),
        ("2a", ("j", "io", "ii"), s2),
        ("2b", ("io", "j", "ii"), s2),
        ("2c", ("io", "ii", "j"), s2),
    ]
