"""Higher-order-function AST — the paper's DSL.

Nodes mirror the paper's primitives:

* ``MapN(f, args)``   — the n-ary ``nzip`` (eq 20); ``len(args) == 1`` is ``map``.
* ``RNZ(r, f, args)`` — reduce-of-nzip (eq 26): ``r`` must be associative;
  ``f`` zips the slices elementwise before reduction.
* ``Subdiv/Flatten/Flip`` — the logical layout operators of §2.1 lifted to
  expressions.
* ``Lam/App/Var/Prim/Lit`` — a tiny lambda calculus to host the rewrite rules
  (the paper's implementation does the same with catamorphisms over an AST
  with lambda abstraction/application nodes).

All HoFs consume the *outermost* dimension of their array arguments.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Tuple


class Expr:
    """Base class; all subclasses are frozen dataclasses (structural equality)."""

    __slots__ = ()


@dataclasses.dataclass(frozen=True)
class Var(Expr):
    name: str

    def __repr__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class Lit(Expr):
    value: float

    def __repr__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True)
class Prim(Expr):
    """A named primitive scalar function ('+', '*', 'max', ...).

    Primitives broadcast over logical arrays, which makes ``lift r``
    (paper eq 41) definitionally equal to ``r`` for primitive reducers.
    """

    name: str

    def __repr__(self):
        return f"({self.name})"


@dataclasses.dataclass(frozen=True)
class Lam(Expr):
    params: Tuple[str, ...]
    body: Expr

    def __repr__(self):
        return f"(\\{' '.join(self.params)} -> {self.body!r})"


@dataclasses.dataclass(frozen=True)
class App(Expr):
    fn: Expr
    args: Tuple[Expr, ...]

    def __repr__(self):
        return f"({self.fn!r} {' '.join(map(repr, self.args))})"


@dataclasses.dataclass(frozen=True)
class MapN(Expr):
    """n-ary zip (``nzip``): apply ``f`` elementwise over the outermost dim."""

    f: Expr
    args: Tuple[Expr, ...]

    def __repr__(self):
        return f"(nzip {self.f!r} {' '.join(map(repr, self.args))})"


@dataclasses.dataclass(frozen=True)
class RNZ(Expr):
    """reduce-of-nzip: ``rnz r f xs…`` (paper eq 26)."""

    r: Expr
    f: Expr
    args: Tuple[Expr, ...]

    def __repr__(self):
        return f"(rnz {self.r!r} {self.f!r} {' '.join(map(repr, self.args))})"


@dataclasses.dataclass(frozen=True)
class Subdiv(Expr):
    d: int
    b: int
    x: Expr

    def __repr__(self):
        return f"(subdiv {self.d} {self.b} {self.x!r})"


@dataclasses.dataclass(frozen=True)
class Flatten(Expr):
    d: int
    x: Expr

    def __repr__(self):
        return f"(flatten {self.d} {self.x!r})"


@dataclasses.dataclass(frozen=True)
class Flip(Expr):
    d1: int
    d2: int
    x: Expr

    def __repr__(self):
        return f"(flip {self.d1} {self.d2} {self.x!r})"


@dataclasses.dataclass(frozen=True)
class FnProd(Expr):
    """Function product ``(f, g)`` ((***) in Control.Arrow; paper eq 31-34)."""

    fs: Tuple[Expr, ...]

    def __repr__(self):
        return f"({' *** '.join(map(repr, self.fs))})"


@dataclasses.dataclass(frozen=True)
class FanOut(Expr):
    """``fanOut f g`` — apply each function to the same argument (paper eq 32)."""

    fs: Tuple[Expr, ...]

    def __repr__(self):
        return f"({' &&& '.join(map(repr, self.fs))})"


@dataclasses.dataclass(frozen=True)
class Tup(Expr):
    items: Tuple[Expr, ...]

    def __repr__(self):
        return f"({', '.join(map(repr, self.items))})"


@dataclasses.dataclass(frozen=True)
class Proj(Expr):
    i: int
    x: Expr

    def __repr__(self):
        return f"(proj {self.i} {self.x!r})"


# ---------------------------------------------------------------------------
# structural helpers
# ---------------------------------------------------------------------------

_fresh_counter = itertools.count()


def fresh(prefix: str = "v") -> str:
    return f"{prefix}_{next(_fresh_counter)}"


def children(e: Expr) -> Tuple[Expr, ...]:
    if isinstance(e, (Var, Lit, Prim)):
        return ()
    if isinstance(e, Lam):
        return (e.body,)
    if isinstance(e, App):
        return (e.fn,) + e.args
    if isinstance(e, MapN):
        return (e.f,) + e.args
    if isinstance(e, RNZ):
        return (e.r, e.f) + e.args
    if isinstance(e, (Subdiv, Flatten, Flip, Proj)):
        return (e.x,)
    if isinstance(e, Tup):
        return e.items
    if isinstance(e, (FnProd, FanOut)):
        return e.fs
    raise TypeError(type(e))


def rebuild(e: Expr, kids: Tuple[Expr, ...]) -> Expr:
    if isinstance(e, (Var, Lit, Prim)):
        return e
    if isinstance(e, Lam):
        return Lam(e.params, kids[0])
    if isinstance(e, App):
        return App(kids[0], tuple(kids[1:]))
    if isinstance(e, MapN):
        return MapN(kids[0], tuple(kids[1:]))
    if isinstance(e, RNZ):
        return RNZ(kids[0], kids[1], tuple(kids[2:]))
    if isinstance(e, Subdiv):
        return Subdiv(e.d, e.b, kids[0])
    if isinstance(e, Flatten):
        return Flatten(e.d, kids[0])
    if isinstance(e, Flip):
        return Flip(e.d1, e.d2, kids[0])
    if isinstance(e, Proj):
        return Proj(e.i, kids[0])
    if isinstance(e, Tup):
        return Tup(tuple(kids))
    if isinstance(e, FnProd):
        return FnProd(tuple(kids))
    if isinstance(e, FanOut):
        return FanOut(tuple(kids))
    raise TypeError(type(e))


def free_vars(e: Expr) -> frozenset:
    if isinstance(e, Var):
        return frozenset((e.name,))
    if isinstance(e, Lam):
        return free_vars(e.body) - frozenset(e.params)
    out = frozenset()
    for c in children(e):
        out |= free_vars(c)
    return out


def subst(e: Expr, env: dict) -> Expr:
    """Capture-avoiding substitution of variables by expressions."""
    if isinstance(e, Var):
        return env.get(e.name, e)
    if isinstance(e, (Lit, Prim)):
        return e
    if isinstance(e, Lam):
        env2 = {k: v for k, v in env.items() if k not in e.params}
        if not env2:
            return e
        # rename bound params that would capture free vars of substitutes
        danger = frozenset().union(*(free_vars(v) for v in env2.values()))
        params, renames = [], {}
        for p in e.params:
            if p in danger:
                np_ = fresh(p)
                renames[p] = Var(np_)
                params.append(np_)
            else:
                params.append(p)
        body = subst(e.body, renames) if renames else e.body
        return Lam(tuple(params), subst(body, env2))
    kids = tuple(subst(c, env) for c in children(e))
    return rebuild(e, kids)


def alpha_normalize(e: Expr, counter=None) -> Expr:
    """Canonical bound-variable names, for structural equality in tests."""
    if counter is None:
        counter = itertools.count()

    def go(e: Expr, env: dict) -> Expr:
        if isinstance(e, Var):
            return Var(env.get(e.name, e.name))
        if isinstance(e, (Lit, Prim)):
            return e
        if isinstance(e, Lam):
            new = {p: f"x{next(counter)}" for p in e.params}
            return Lam(tuple(new.values()), go(e.body, {**env, **new}))
        return rebuild(e, tuple(go(c, env) for c in children(e)))

    return go(e, {})


def size(e: Expr) -> int:
    return 1 + sum(size(c) for c in children(e))


# ---------------------------------------------------------------------------
# sugar used throughout tests / benchmarks
# ---------------------------------------------------------------------------


def lam(params, body) -> Lam:
    if isinstance(params, str):
        params = (params,)
    return Lam(tuple(params), body)


def v(name: str) -> Var:
    return Var(name)


def zip2(f: Expr, x: Expr, y: Expr) -> MapN:
    return MapN(f, (x, y))


def map1(f: Expr, x: Expr) -> MapN:
    return MapN(f, (x,))


def reduce1(r: Expr, x: Expr) -> RNZ:
    """``reduce r x`` — rnz with identity zipper (paper eq 16 via eq 26)."""
    return RNZ(r, Prim("id"), (x,))


def dot(u: Expr, vv: Expr) -> RNZ:
    """``dot u v = rnz (+) (*) u v`` (paper eq 29)."""
    return RNZ(Prim("+"), Prim("*"), (u, vv))


def lift(r: Expr) -> Lam:
    """``lift r`` (paper eq 41): raise a binary function to operate on arrays.

    For Prim reducers this is semantically the identity (prims broadcast),
    but the explicit form is needed when the exchange rule wraps a closure.
    """
    a, b = fresh("la"), fresh("lb")
    return Lam((a, b), MapN(r, (Var(a), Var(b))))


def ncomp(i: int, f: Expr, g: Expr, n: int, m: int) -> Lam:
    """Generalized composition (paper eq 23).

    Compose ``g`` (arity ``m``) before the ``i``-th argument of ``f``
    (arity ``n``).  Result arity is ``n - 1 + m``.
    """
    a_params = [fresh("a") for _ in range(n)]
    b_params = [fresh("b") for _ in range(m)]
    params = a_params[:i] + b_params + a_params[i + 1 :]
    inner = App(g, tuple(Var(p) for p in b_params))
    args = tuple(
        inner if k == i else Var(a_params[k]) for k in range(n)
    )
    return Lam(tuple(params), App(f, args))


def arity(f: Expr) -> int | None:
    """Syntactic arity of a function expression, if known."""
    from .interp import PRIMS  # local import to avoid cycle

    if isinstance(f, Lam):
        return len(f.params)
    if isinstance(f, Prim):
        return PRIMS[f.name].arity
    return None
