"""Deterministic synthetic data pipeline with per-host sharding.

Every batch is a pure function of (seed, step, host) — no filesystem, no
coordination, bit-reproducible across restarts.  That determinism is load-
bearing for fault tolerance: after a restore to step N, host h regenerates
exactly the batch it would have seen, so data order survives crashes and
elastic resizes (the host count enters the hash, and the global batch is
carved by host *rank range*, not modulo, so growing hosts re-partitions
cleanly).

The token stream is Zipf-distributed with a deterministic per-document
structure, which is enough signal for the loss to fall measurably within a
few hundred steps of the example trainer (examples/train_100m.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    n_hosts: int = 1
    host_id: int = 0


def _rng_for(cfg: DataConfig, step: int, sample: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, sample])
    )


def _sample_doc(rng: np.random.Generator, cfg: DataConfig, length: int):
    # zipf over the vocab with a deterministic "grammar": token t is followed
    # by (t*7+3) % vocab with prob .5 — gives the LM something learnable.
    toks = np.minimum(
        rng.zipf(cfg.zipf_a, size=length) - 1, cfg.vocab - 1
    ).astype(np.int32)
    follow = (toks * 7 + 3) % cfg.vocab
    coin = rng.random(length) < 0.5
    toks[1:] = np.where(coin[1:], follow[:-1], toks[1:])
    return toks


def host_batch_slice(cfg: DataConfig) -> range:
    per = cfg.global_batch // cfg.n_hosts
    return range(cfg.host_id * per, (cfg.host_id + 1) * per)


def batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The host's shard of the global batch for ``step``."""
    rows = []
    for sample in host_batch_slice(cfg):
        rng = _rng_for(cfg, step, sample)
        rows.append(_sample_doc(rng, cfg, cfg.seq_len + 1))
    arr = np.stack(rows)
    return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
