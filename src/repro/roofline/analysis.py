"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads the per-cell JSONs written by launch.dryrun and derives the three
terms per (arch x shape x mesh):

    compute_s    = HLO_FLOPs / (chips x 197e12)       [bf16 peak/chip]
    memory_s     = HLO_bytes / (chips x 819e9)        [HBM BW/chip]
    collective_s = collective_bytes / (chips x 50e9)  [ICI link BW]

cost_analysis() on the SPMD-partitioned module reports *per-device* numbers,
and the collective shapes in the partitioned HLO are per-device shards, so
all three terms are already per-chip; chips only enters MODEL_FLOPS ratios.

MODEL_FLOPS (the useful-work floor) is 6·N_active·tokens for training and
2·N_active·tokens for inference; the ratio against total HLO_FLOPs exposes
remat recompute and sharding-induced redundancy.

Caveat on the memory term: the CPU-backend HLO has no TPU fusion decisions,
so the bytes estimate (dot operands/outputs + every non-bookkeeping op
output) is an UPPER BOUND — on the real chip most elementwise intermediates
stay in VMEM.  ``dot_bytes`` alone (in the JSON) is the corresponding floor.
Terms are comparable across variants, which is what the §Perf loop needs.
"""

from __future__ import annotations

import glob
import json
import math
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s / chip
ICI_BW = 50e9         # B/s / link

#: bytes a ring algorithm moves per device, as a multiple of the payload:
#: ring all-reduce sends the payload twice (reduce-scatter + all-gather),
#: the one-phase collectives once.  The (shards-1)/shards factor is applied
#: by ``collective_seconds``.
COLLECTIVE_BYTE_FACTOR = {
    "psum": 2.0,          # lax.psum lowers to an all-reduce
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
}


def collective_seconds(
    kind: str, nbytes: float, shards: int, hw_ici_bw: float = ICI_BW
) -> float:
    """Per-device link time of one collective over ``shards`` participants.

    Ring-algorithm byte model: a payload of ``nbytes`` costs
    ``factor * nbytes * (shards - 1) / shards`` bytes on the busiest link,
    where ``factor`` is 2 for all-reduce (reduce-scatter then all-gather)
    and 1 for the single-phase collectives.  This is the interconnect half
    of the roofline the mesh-tier search scores against (``search.beam``).
    """
    if shards <= 1:
        return 0.0
    factor = COLLECTIVE_BYTE_FACTOR[kind]
    return factor * nbytes * (shards - 1) / shards / hw_ici_bw


def sharded_reduce_seconds(
    nbytes: float,
    shards: int,
    *,
    collective: str = "psum",
    compute_s: float = 0.0,
    hw_ici_bw: float = ICI_BW,
) -> float:
    """Exposed communication time to finish a mesh-sharded reduction.

    ``psum``: a plain all-reduce of the per-device partial output — fully
    exposed (the kernel must finish before the collective starts).

    ``ring``: the ring-overlap lowering (``codegen.collectives.ring_psum``,
    promoted from ``launch.overlap``): the reduce-scatter phase pipelines
    behind the partial-product compute (each ppermute hop hides behind the
    next chunk's MXU work, Wang et al.-style), so only the part of it that
    exceeds ``compute_s`` plus the trailing all-gather is exposed.
    """
    if shards <= 1:
        return 0.0
    if collective == "ring":
        rs = collective_seconds("reduce-scatter", nbytes, shards, hw_ici_bw)
        ag = collective_seconds("all-gather", nbytes, shards, hw_ici_bw)
        return max(rs - compute_s, 0.0) + ag
    return collective_seconds("psum", nbytes, shards, hw_ici_bw)


def attention_rescale_seconds(
    h: int, s: int, e: int, t_steps: int, peak: float = PEAK_FLOPS
) -> float:
    """VPU time of the online-softmax running state per KV block.

    Every sequential KV step of the fused attention kernel rescales the
    (h, s) running max/sum and the (h, s, e) accumulator by
    ``alpha = exp(m_prev - m_next)`` — roughly ``e + 4`` elementwise ops
    per query row per step, work that a one-pass softmax (``t_steps == 1``)
    does not pay.  The beam adds this term so it can trade smaller KV
    chunks (less VMEM) against the extra rescale traffic; with ``t``
    defaulted to its whole extent the term is minimal, which keeps the
    bound cut sound for partial states.
    """
    return t_steps * h * s * (e + 4) / peak


def grouped_tail_factor(group_sizes, bm: int) -> float:
    """Occupancy loss of the ragged tails in a grouped matmul, >= 1.

    The group-offset kernel walks each group's rows in ``bm``-sized tiles,
    so a group of ``s_g`` rows issues ``ceil(s_g / bm)`` tiles and the
    MXU processes ``ceil(s_g / bm) * bm`` rows of work for ``s_g`` rows of
    output.  The factor is the issued/useful row ratio over all groups —
    1.0 when every group size divides ``bm``; empty groups cost nothing
    (their tile loop is skipped entirely).
    """
    useful = sum(group_sizes)
    if useful <= 0 or bm <= 0:
        return 1.0
    issued = sum(-(-s // bm) * bm for s in group_sizes if s > 0)
    return max(issued / useful, 1.0)


#: storage bytes per element of the quantized tiers (core.enumerate
#: QuantMeta dtypes plus CLI-format aliases)
QUANT_STORAGE_BYTES = {
    "int8": 1,
    "float8_e4m3fn": 1,
    "fp8": 1,
}

#: accumulator/output bytes per element (int32 / float32 both 4)
QUANT_ACCUM_BYTES = 4


def quant_byte_model(quant, elem_bytes: int):
    """(operand_bytes, out_bytes) per element for a maybe-quantized spec.

    ``quant`` is a ``core.enumerate.QuantMeta`` (or None).  Operands of a
    quantized contraction stream from HBM at storage precision (1 byte);
    the output leaves at accumulator precision (4 bytes — int32 for int8,
    f32 for fp8) since the dequant epilogue keeps real values.  Non-quant
    specs keep the caller's ``elem_bytes`` on both sides — this is the
    memory-bandwidth advantage the beam scores when it trades precision
    tiers (``search.beam.estimate``) and the bench gate checks
    (``scripts/bench_smoke.py --quant``).
    """
    if quant is None:
        return elem_bytes, elem_bytes
    return QUANT_STORAGE_BYTES[quant.dtype], QUANT_ACCUM_BYTES


def quant_hbm_bytes(spec, elem_bytes: int = 4) -> float:
    """One-pass HBM byte floor of a contraction: read every operand once,
    write the output once, at the spec's storage precisions."""
    import math as _math

    root = spec.root()
    op_b, out_b = quant_byte_model(getattr(root, "quant", None), elem_bytes)
    read = sum(
        _math.prod(root.extents[i] for i in axes) * op_b
        for axes in root.operands.values()
    )
    write = _math.prod(root.extents[i] for i in root.output) * out_b
    return float(read + write)


_SUGGEST = {
    "compute": "raise arithmetic efficiency: larger per-chip batch or less "
               "remat recompute (MODEL/HLO flops ratio shows the headroom)",
    "memory": "cut HBM traffic: fuse elementwise chains into the matmul "
              "epilogues (paper eq 27) and keep KV/activations in bf16",
    "collective": "re-shard to cheaper collectives: move the all-gather off "
                  "the critical path (overlapped collective matmul) or "
                  "shard the other operand dim (paper's flip exchange)",
}


def param_counts(arch: str) -> Dict[str, float]:
    """Total and active parameter counts via eval_shape (no allocation)."""
    import jax

    from ..configs import get_config
    from ..models.api import get_api

    cfg = get_config(arch)
    api = get_api(cfg)
    shapes = jax.eval_shape(lambda k: api.init(cfg, k)[0], jax.random.key(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0
    expert = 0
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        total += n
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if "moe" in keys and "shared" not in keys and "router" not in keys:
            expert += n
    active = total
    if cfg.moe is not None and expert:
        active = total - expert * (1 - cfg.moe.top_k / cfg.moe.n_experts)
    return {"total": float(total), "active": float(active)}


def model_flops(arch: str, shape_name: str, counts: Dict[str, float]) -> float:
    from ..configs import SHAPES

    s = SHAPES[shape_name]
    n = counts["active"]
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len
        return 6.0 * n * tokens
    if s.kind == "prefill":
        tokens = s.global_batch * s.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * s.global_batch


def analyze_cell(rec: Dict, counts: Optional[Dict] = None) -> Dict:
    if rec["status"] != "ok":
        return dict(rec)
    chips = rec["chips"]
    parsed = rec.get("parsed")
    if parsed:  # trip-count-aware numbers from roofline.hlo_parse
        flops = parsed["dot_flops"]
        # HBM estimate: dot operand/output traffic + non-dot materialized
        # outputs (out_bytes_proxy excludes dots and bookkeeping ops);
        # legacy records (no dot_bytes) fall back to the raw proxy
        if "dot_bytes" in parsed:
            mem_bytes = parsed["dot_bytes"] + parsed["out_bytes_proxy"]
        else:
            mem_bytes = parsed["out_bytes_proxy"]
        coll_bytes = parsed["collective_bytes"]
    else:  # legacy records: while bodies counted once (undercounts!)
        flops = rec["flops"]
        mem_bytes = rec["bytes_accessed"]
        coll_bytes = sum(
            v for k, v in rec["collectives"].items() if k != "count"
        )
    compute_s = flops / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    out = dict(rec)
    out.update(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        collective_bytes=coll_bytes,
        dominant=dominant,
        suggestion=_SUGGEST[dominant],
    )
    if counts:
        mf = model_flops(rec["arch"], rec["shape"], counts)
        total_hlo = flops * chips
        out["model_flops"] = mf
        out["useful_ratio"] = mf / total_hlo if total_hlo else 0.0
        # roofline fraction: time the chip MUST spend vs time it spends.
        # Conservative = materialize-everything memory bound; fused = memory
        # floor (dot traffic only), the realistic number on a TPU whose
        # fusion keeps elementwise intermediates in VMEM.
        ideal = (mf / chips) / PEAK_FLOPS
        bound = max(compute_s, memory_s, collective_s)
        out["roofline_fraction"] = ideal / bound if bound else 0.0
        if parsed and "dot_bytes" in parsed:
            mem_fused_s = parsed["dot_bytes"] / HBM_BW
            bound_fused = max(compute_s, mem_fused_s, collective_s)
            out["memory_fused_s"] = mem_fused_s
            out["roofline_fraction_fused"] = (
                ideal / bound_fused if bound_fused else 0.0
            )
            out["dominant_fused"] = max(
                ("compute", compute_s), ("memory", mem_fused_s),
                ("collective", collective_s),
                key=lambda kv: kv[1],
            )[0]
    return out


def load_results(results_dir: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def analyze_all(results_dir: str, with_counts: bool = True) -> List[Dict]:
    cache: Dict[str, Dict] = {}
    rows = []
    for rec in load_results(results_dir):
        counts = None
        if with_counts and rec["status"] == "ok":
            if rec["arch"] not in cache:
                cache[rec["arch"]] = param_counts(rec["arch"])
            counts = cache[rec["arch"]]
        rows.append(analyze_cell(rec, counts))
    return rows


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(rows: List[Dict], mesh: Optional[str] = None) -> str:
    lines = [
        "| arch | shape | mesh | step | compute | memory(ub) | mem(fused) "
        "| collective | bound(fused) | MODEL/HLO | frac | frac(fused) |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if mesh and r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} | — | "
                f"skipped | — | — | — | — | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} | — | "
                f"ERROR | — | — | — | — | — | — | — |"
            )
            continue
        lines.append(
            "| {arch} | {shape} | {mesh} | {step} | {c} | {m} | {mf} | {k} "
            "| {dom} | {ur:.2f} | {rf:.3f} | {rff:.3f} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                step=r["step"].replace("_step", ""),
                c=_fmt_s(r["compute_s"]), m=_fmt_s(r["memory_s"]),
                mf=_fmt_s(r.get("memory_fused_s", 0.0)),
                k=_fmt_s(r["collective_s"]),
                dom=r.get("dominant_fused", r["dominant"]),
                ur=r.get("useful_ratio", 0.0),
                rf=r.get("roofline_fraction", 0.0),
                rff=r.get("roofline_fraction_fused", 0.0),
            )
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = analyze_all(args.results)
    print(markdown_table(rows, mesh=args.mesh))


if __name__ == "__main__":
    main()
