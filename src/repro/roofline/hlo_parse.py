"""Trip-count-aware cost extraction from compiled HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scanned layer stacks by a factor of n_layers (and remat loops on
top).  This parser walks the HLO module, finds each while loop's trip count
(the canonical scan form compares the induction variable against an s32
constant inside the condition computation), and accumulates per-computation:

  * dot FLOPs        (2 * prod(output shape) * contracted extent, operand
                      shapes resolved through a per-computation symbol table)
  * collective bytes (payload of all-gather/all-reduce/reduce-scatter/
                      all-to-all/collective-permute; -start tuples halved)
  * op output bytes  (a proxy for HBM traffic)

then resolves the call graph from ENTRY, multiplying by enclosing trip
counts.  Only dot/convolution flops are counted — elementwise flops are
noise for these models — so the compute term is a *dot roofline*, the honest
number for MXU utilization.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\w+\[[\d,]*\])")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
def _split_operands(opstr: str) -> List[str]:
    """Split a dot operand list on commas OUTSIDE []/{} (shape commas)."""
    out, depth, cur = [], 0, []
    for ch in opstr:
        if ch in "[{(":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _first_shape(type_str: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, tuple(int(d) for d in dims.split(",") if d)


def _all_shapes_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0  # dot operand + output bytes (HBM traffic floor)
    coll_bytes: float = 0.0
    coll_by_type: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    out_bytes: float = 0.0
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    calls: List[str] = dataclasses.field(default_factory=list)
    max_const: int = 0


#: ops whose outputs are aliases/bookkeeping, not HBM materializations —
#: excluded from the bytes proxy (a loop-carried tuple GTE would otherwise
#: count the whole stacked parameter tree once per scan step)
_NO_TRAFFIC_OPS = (
    "get-tuple-element", "tuple(", "parameter(", "constant(", "bitcast(",
    "while(", "conditional(", "after-all(", "custom-call(",
)


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    headers: Dict[str, str] = {}
    cur: Optional[str] = None
    entry_name = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if cur is None:
            if s.endswith("{") and "->" in s and "(" in s:
                head = s[: s.find("(")].strip()
                name = head.split()[-1].lstrip("%")
                if not name:
                    continue
                cur = name
                comps[cur] = []
                headers[cur] = s
                if s.startswith("ENTRY"):
                    entry_name = cur
            continue
        if s == "}" or s.startswith("} "):
            cur = None
            continue
        comps[cur].append(s)
    # prepend headers so param shapes are visible to the symbol pass
    for name, h in headers.items():
        comps[name].insert(0, "//HEADER// " + h)
    return comps, entry_name


def _analyze_computation(lines: List[str]) -> CompStats:
    st = CompStats()
    symbols: Dict[str, Tuple[str, Tuple[int, ...]]] = {}

    # pass 1: symbol table (defs + header params)
    for s in lines:
        if s.startswith("//HEADER//"):
            for name, ty in _PARAM_RE.findall(s):
                sh = _first_shape(ty)
                if sh:
                    symbols[name] = sh
            continue
        m = _DEF_RE.match(s)
        if m:
            sh = _first_shape(m.group(2))
            if sh:
                symbols[m.group(1)] = sh

    # pass 2: stats
    for s in lines:
        if s.startswith("//HEADER//"):
            continue
        for mc in _CONST_RE.finditer(s):
            st.max_const = max(st.max_const, int(mc.group(1)))
        m = _DEF_RE.match(s)
        if not m:
            continue
        rhs = m.group(2)

        if " dot(" in rhs:
            idx = rhs.find(" dot(")
            out = _first_shape(rhs[:idx])
            opm = _OPERANDS_RE.search(rhs[idx:])
            ctm = _CONTRACT_RE.search(rhs)
            if out:
                out_elems = 1
                for d in out[1]:
                    out_elems *= d
                contract = 0
                op_bytes = 0.0
                if opm:
                    # one entry per operand token, positional: jax<=0.4.x
                    # prints inline types (``f32[8,16]{1,0} %name``),
                    # newer HLO just ``%name`` (sigil optional) — resolve
                    # the type if present, else the symbol table
                    shapes = []
                    for tok in _split_operands(opm.group(1)):
                        sh = _first_shape(tok)
                        if sh is None and tok:
                            nm = tok.split()[-1].lstrip("%")
                            sh = symbols.get(nm)
                        shapes.append(sh)
                    for sh in shapes:
                        if sh:
                            n = 1
                            for d in sh[1]:
                                n *= d
                            op_bytes += n * _DTYPE_BYTES.get(sh[0], 4)
                    lhs = shapes[0] if shapes else None
                    if lhs and ctm:
                        dims = [int(d) for d in ctm.group(1).split(",") if d]
                        contract = 1
                        for d in dims:
                            if d < len(lhs[1]):
                                contract *= lhs[1][d]
                    elif lhs and lhs[1]:
                        contract = lhs[1][-1]
                if contract == 0:
                    contract = 1
                st.dot_flops += 2.0 * out_elems * contract
                st.dot_bytes += op_bytes + out_elems * _DTYPE_BYTES.get(
                    out[0], 4
                )
        elif " convolution(" in rhs:
            out = _first_shape(rhs[: rhs.find(" convolution(")])
            if out:
                out_elems = 1
                for d in out[1]:
                    out_elems *= d
                st.dot_flops += 2.0 * out_elems  # lower bound

        for coll in _COLLECTIVES:
            started = f" {coll}-start(" in rhs
            plain = f" {coll}(" in rhs
            if not (started or plain):
                continue
            tok = f" {coll}-start(" if started else f" {coll}("
            idx = rhs.find(tok)
            type_str = rhs[:idx]
            b = _all_shapes_bytes(type_str)
            if started and type_str.strip().startswith("("):
                b //= 2
            st.coll_bytes += b
            st.coll_by_type[coll] += b
            break

        mw = _WHILE_RE.search(rhs)
        if mw:
            st.whiles.append((mw.group(1), mw.group(2)))
        else:
            for mc2 in _CALL_RE.finditer(rhs):
                st.calls.append(mc2.group(1))

        # elementwise/materialization proxy: skip bookkeeping ops AND dots
        # (dot traffic is tracked separately in dot_bytes)
        if " dot(" not in rhs and " convolution(" not in rhs and not any(
            tok in rhs for tok in _NO_TRAFFIC_OPS
        ):
            paren = rhs.find("(")
            type_part = rhs[:paren] if paren > 0 else rhs
            st.out_bytes += _all_shapes_bytes(type_part)
    return st


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps, entry = split_computations(hlo)
    stats = {name: _analyze_computation(lines) for name, lines in comps.items()}

    def trip_count(cond_name: str) -> int:
        st = stats.get(cond_name)
        # also look through fusions called by the condition
        best = st.max_const if st else 0
        if st:
            for c in st.calls:
                sub = stats.get(c)
                if sub:
                    best = max(best, sub.max_const)
        return max(best, 1)

    memo: Dict[str, Tuple] = {}

    def resolve(name: str, depth=0):
        if name in memo:
            return memo[name]
        st = stats.get(name)
        zero = (0.0, 0.0, 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES})
        if st is None or depth > 64:
            return zero
        memo[name] = zero  # cycle guard
        flops, coll, byts = st.dot_flops, st.coll_bytes, st.out_bytes
        dbytes = st.dot_bytes
        by_type = dict(st.coll_by_type)
        for callee in st.calls:
            f, c, b, db, bt = resolve(callee, depth + 1)
            flops += f; coll += c; byts += b; dbytes += db
            for k in by_type:
                by_type[k] += bt[k]
        for cond, body in st.whiles:
            n = trip_count(cond)
            f, c, b, db, bt = resolve(body, depth + 1)
            flops += n * f
            coll += n * c
            byts += n * b
            dbytes += n * db
            for k in by_type:
                by_type[k] += n * bt[k]
        memo[name] = (flops, coll, byts, dbytes, by_type)
        return memo[name]

    if entry is None:
        return {"dot_flops": 0.0, "collective_bytes": 0.0,
                "out_bytes_proxy": 0.0, "dot_bytes": 0.0}
    flops, coll, byts, dbytes, by_type = resolve(entry)
    out = {
        "dot_flops": flops,
        "collective_bytes": coll,
        "out_bytes_proxy": byts,
        "dot_bytes": dbytes,
        "n_computations": float(len(comps)),
    }
    for k, v in by_type.items():
        out[f"coll_{k}"] = v
    return out
