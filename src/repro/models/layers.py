"""Shared model layers: norms, rotary, GQA attention (blockwise/flash-style
training path + cached decode path), MLPs, embeddings.

Parameter trees are nested dicts; every init function returns
``(params, axes)`` where ``axes`` mirrors the structure with tuples of
*logical axis names* consumed by ``launch.sharding`` (the distributed-level
realization of the paper's subdiv: a mesh axis is just the outermost
subdivision of that logical dimension).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .. import ops

F32 = jnp.float32
NEG_INF = -1e30


def remat(fn):
    """Activation-checkpoint a scan body under the active remat policy.

    $REPRO_REMAT_POLICY: 'nothing' (default — recompute everything, incl.
    re-gathering FSDP weights in backward), 'dots' (save matmul outputs —
    trades HBM for skipping the backward re-gather), 'dots_no_batch'.
    A §Perf knob; see EXPERIMENTS.md.
    """
    import os

    pol = os.environ.get("REPRO_REMAT_POLICY", "nothing")
    if pol == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots
        )
    if pol == "dots_no_batch":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    return jax.checkpoint(fn)


class PA:
    """A (param value, logical axes) pair.

    Deliberately NOT a pytree: ``jax.tree.map`` treats it as a leaf, so
    building/stacking annotated parameter trees never descends into the axis
    metadata.  ``split_params`` separates the twins at the end of init.
    """

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value, self.axes = value, axes


def split_params(tree):
    """Split a PA-leaf tree into (params, axes) twins."""
    if isinstance(tree, dict):
        p, a = {}, {}
        for k, v in tree.items():
            p[k], a[k] = split_params(v)
        return p, a
    return tree.value, tree.axes


def stack_annotated(trees):
    """Stack a list of PA-leaf trees along a new leading axis."""
    return jax.tree.map(
        lambda *xs: PA(jnp.stack([x.value for x in xs]), xs[0].axes),
        *trees,
        is_leaf=lambda x: isinstance(x, PA),
    )


def _init(key, shape, axes, dtype, scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    w = jax.random.normal(key, shape, dtype=F32) * scale
    return PA(w.astype(dtype), axes)


def _zeros(shape, axes, dtype):
    return PA(jnp.zeros(shape, dtype=dtype), axes)


def _ones(shape, axes, dtype):
    return PA(jnp.ones(shape, dtype=dtype), axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(cfg: ModelConfig, dim=None):
    dim = dim or cfg.d_model
    return {"scale": _ones((dim,), ("embed",), F32)}


def rmsnorm(params, x, eps: float):
    h = x.astype(F32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    out = h * lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_init(cfg: ModelConfig, dim=None):
    dim = dim or cfg.d_model
    return {
        "scale": _ones((dim,), ("embed",), F32),
        "bias": _zeros((dim,), ("embed",), F32),
    }


def layernorm(params, x, eps: float):
    h = x.astype(F32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    out = (h - mu) * lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, N, hd), positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=F32) / half)
    )
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(F32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate(
        (x1 * cos - x2 * sin, x2 * cos + x1 * sin), axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, h * hd), ("embed", "heads"), dt),
        "wk": _init(ks[1], (d, kv * hd), ("embed", "kv"), dt),
        "wv": _init(ks[2], (d, kv * hd), ("embed", "kv"), dt),
        "wo": _init(ks[3], (h * hd, d), ("heads", "embed"), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = _zeros((h * hd,), ("heads",), dt)
        p["bk"] = _zeros((kv * hd,), ("kv",), dt)
        p["bv"] = _zeros((kv * hd,), ("kv",), dt)
    if cfg.qk_norm:
        p["q_norm"] = _ones((hd,), (None,), F32)
        p["k_norm"] = _ones((hd,), (None,), F32)
    return p


def _qk_norm(x, scale, eps):
    h = x.astype(F32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _project_qkv(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = ops.dense(x.reshape(B * S, -1), params["wq"]).reshape(B, S, h, hd)
    k = ops.dense(x.reshape(B * S, -1), params["wk"]).reshape(B, S, kv, hd)
    v = ops.dense(x.reshape(B * S, -1), params["wv"]).reshape(B, S, kv, hd)
    if cfg.qkv_bias:
        q = q + params["bq"].reshape(h, hd)
        k = k + params["bk"].reshape(kv, hd)
        v = v + params["bv"].reshape(kv, hd)
    if cfg.qk_norm:
        q = _qk_norm(q, params["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def blockwise_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, T, KV, hd)
    v: jax.Array,  # (B, T, KV, hd)
    *,
    causal: bool = True,
    q_block: int = 512,
    k_block: int = 512,
    kv_lengths: Optional[jax.Array] = None,  # (B,) valid key counts
) -> jax.Array:
    """Flash-style online-softmax attention: O(S*block) memory, pure JAX.

    This is the rnz-subdivision of the softmax reduction: the key/value
    sequence is ``subdiv``-ed into blocks and the reduction regrouped over
    them (the paper's eq 44' with an online-rescaled monoid).

    ``kv_lengths`` masks out keys at positions >= the per-sequence length
    — the attention half of variable-length (right-padded) prefill.  With
    causal masking and right padding no *real* query row can reach a pad
    key anyway (pads sit after every real position), so real rows are
    bitwise identical with or without it; the mask guarantees pad rows
    cannot leak even on non-causal uses.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    # snap block sizes to divisors of the sequence lengths
    q_block = math.gcd(S, min(q_block, S))
    k_block = math.gcd(T, min(k_block, T))
    nq, nk = S // q_block, T // k_block
    scale = hd ** -0.5

    if nq == 1 and nk == 1 and kv_lengths is None:
        # Single-block path: one unblocked softmax-attention, emitted as
        # the exact primitive chain ``capture.harvest`` recognizes as the
        # fused-attention motif (fold heads -> QK^T -> scale -> [iota
        # causal mask] -> max-shift -> exp -> PV -> div by rowsum), so
        # ``capture.optimize`` can dispatch it through ``ops.attention``.
        # Numerically identical to the blockwise path at nq == nk == 1
        # (same f32 accumulation, no rescale steps).
        qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        kx = k if G == 1 else jnp.repeat(k, G, axis=2)
        vx = v if G == 1 else jnp.repeat(v, G, axis=2)
        kh = kx.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
        vh = vx.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
        s = jnp.einsum(
            "hsd,htd->hst", qh.astype(F32), kh.astype(F32)
        ) * scale
        if causal:
            row = lax.broadcasted_iota(jnp.int32, s.shape, 1)
            col = lax.broadcasted_iota(jnp.int32, s.shape, 2)
            s = jnp.where(col <= row, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        num = jnp.einsum("hst,hte->hse", p, vh.astype(F32))
        out = num / jnp.sum(p, axis=-1, keepdims=True)
        out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
        return out.astype(q.dtype)

    qs = q.reshape(B, nq, q_block, KV, G, hd)
    ks = k.reshape(B, nk, k_block, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, k_block, KV, hd).transpose(1, 0, 2, 3, 4)

    import os

    causal_skip = causal and os.environ.get("REPRO_CAUSAL_SKIP") == "1"

    def per_q_chunk(qi, qc):  # qc: (B, qb, KV, G, hd)
        q_pos = qi * q_block + jnp.arange(q_block)

        def k_body(ki, kc, vc, carry):
            m, l, acc = carry
            s = jnp.einsum(
                "bqkgh,bpkh->bkgqp", qc.astype(F32), kc.astype(F32)
            ) * scale
            k_pos = ki * k_block + jnp.arange(k_block)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            if kv_lengths is not None:
                valid = k_pos[None, :] < kv_lengths[:, None]  # (B, kb)
                s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqp,bpkh->bkgqh", p, vc.astype(F32)
            )
            return (m_new, l_new, acc_new)

        init = (
            jnp.full((B, KV, G, q_block), NEG_INF, F32),
            jnp.zeros((B, KV, G, q_block), F32),
            jnp.zeros((B, KV, G, q_block, hd), F32),
        )
        if causal_skip:
            # §Perf knob: dynamic loop bound skips fully-masked key blocks —
            # the rnz over key blocks only runs up to the causal frontier
            # (~2x fewer attention flops/bytes at long sequence)
            k_hi = (qi * q_block + q_block + k_block - 1) // k_block

            def fori_body(ki, carry):
                kc = lax.dynamic_index_in_dim(ks, ki, 0, keepdims=False)
                vc = lax.dynamic_index_in_dim(vs, ki, 0, keepdims=False)
                return k_body(ki, kc, vc, carry)

            m, l, acc = lax.fori_loop(0, k_hi, fori_body, init)
        else:
            def k_step(carry, inp):
                ki, kc, vc = inp
                return k_body(ki, kc, vc, carry), None

            (m, l, acc), _ = lax.scan(
                k_step, init, (jnp.arange(nk), ks, vs)
            )
        out = acc / l[..., None]
        return out  # (B, KV, G, qb, hd)

    outs = jax.vmap(per_q_chunk, in_axes=(0, 1), out_axes=1)(
        jnp.arange(nq), qs
    )  # (B, nq, KV, G, qb, hd)
    out = outs.transpose(0, 1, 4, 2, 3, 5).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,       # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, T, KV, hd)
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,) valid lengths (including current token)
) -> jax.Array:
    B, _, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum(
        "bkgh,btkh->bkgt", qg.astype(F32), k_cache.astype(F32)
    ) * scale
    valid = jnp.arange(T)[None, :] < cache_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(F32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attention_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    cache: Optional[Dict] = None,
    q_block: int = 512,
    k_block: int = 512,
    lengths: Optional[jax.Array] = None,
):
    """Returns (y, new_cache).  cache = {k, v, len} for decode.

    ``lengths`` (B,) marks right-padded prefill: keys past each
    sequence's true length are masked out of the attention and the cache
    ``len`` starts at the true length (not the padded S), so decode
    writes its first token over the first pad slot and never attends pad
    KV — the fix for the mixed-length batching leak.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    if cache is None:
        y = blockwise_attention(
            q, k, v, causal=causal, q_block=q_block, k_block=k_block,
            kv_lengths=lengths,
        )
        new_cache = None
    elif S == 1:
        idx = cache["len"]  # (B,) current write positions
        bidx = jnp.arange(B)
        k_cache = cache["k"].at[bidx, idx].set(k[:, 0])
        v_cache = cache["v"].at[bidx, idx].set(v[:, 0])
        y = decode_attention(q, k_cache, v_cache, idx + 1)
        new_cache = {"k": k_cache, "v": v_cache, "len": idx + 1}
    else:
        # prefill into an empty cache
        T = cache["k"].shape[1]
        k_cache = lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
        )
        v_cache = lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
        )
        y = blockwise_attention(
            q, k, v, causal=causal, q_block=q_block, k_block=k_block,
            kv_lengths=lengths,
        )
        new_cache = {
            "k": k_cache, "v": v_cache,
            "len": (jnp.full((B,), S, jnp.int32) if lengths is None
                    else lengths.astype(jnp.int32)),
        }
    y = ops.dense(y.reshape(B * S, -1), params["wo"]).reshape(B, S, -1)
    return y, new_cache


def attention_cache_init(
    cfg: ModelConfig, batch: int, max_len: int, dtype=None
):
    dtype = dtype or cfg.param_dtype
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


#: logical axes of the attention cache (for sharding long-context decode)
CACHE_AXES = {"k": ("batch", "seq_kv", "kv", None),
              "v": ("batch", "seq_kv", "kv", None),
              "len": ("batch",)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":  # SwiGLU
        return {
            "w_gate": _init(ks[0], (d, f), ("embed", "mlp"), dt),
            "w_up": _init(ks[1], (d, f), ("embed", "mlp"), dt),
            "w_down": _init(ks[2], (f, d), ("mlp", "embed"), dt),
        }
    return {  # plain 2-layer (whisper-style gelu)
        "w1": _init(ks[0], (d, f), ("embed", "mlp"), dt),
        "b1": _zeros((f,), ("mlp",), dt),
        "w2": _init(ks[1], (f, d), ("mlp", "embed"), dt),
        "b2": _zeros((d,), ("embed",), dt),
    }


def mlp_apply(params, cfg: ModelConfig, x):
    B, S, D = x.shape
    h = x.reshape(B * S, D)
    if cfg.act == "silu":
        g = ops.dense(h, params["w_gate"])
        u = ops.dense(h, params["w_up"])
        out = ops.dense(jax.nn.silu(g.astype(F32)).astype(x.dtype) * u,
                        params["w_down"])
    else:
        h1 = jax.nn.gelu(
            (ops.dense(h, params["w1"]) + params["b1"]).astype(F32)
        ).astype(x.dtype)
        out = ops.dense(h1, params["w2"]) + params["b2"]
    return out.reshape(B, S, D)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------


def embedding_init(key, cfg: ModelConfig):
    dt = cfg.param_dtype
    ks = jax.random.split(key, 2)
    p = {"tok": _init(ks[0], (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                      dt, scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = _init(
            ks[1], (cfg.d_model, cfg.vocab), ("embed", "vocab"), dt
        )
    return p


def embed(params, tokens):
    return params["tok"][tokens]


def logits(params, cfg: ModelConfig, x):
    B, S, D = x.shape
    w = (
        params["tok"].T if cfg.tie_embeddings else params["unembed"]
    )
    return jnp.dot(
        x.reshape(B * S, D), w, preferred_element_type=F32
    ).reshape(B, S, -1)


def cross_entropy(logits_: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL, numerically stable, f32.

    The gold logit is extracted with a fused one-hot multiply-reduce rather
    than take_along_axis: under a vocab-sharded unembed (TP) this keeps the
    reduction local per shard + one small all-reduce, instead of gathering
    the full (tokens, vocab) logits to pick one column.
    """
    logits_ = logits_.astype(F32)
    lse = jax.scipy.special.logsumexp(logits_, axis=-1)
    onehot = jax.nn.one_hot(labels, logits_.shape[-1], dtype=F32)
    gold = jnp.sum(logits_ * onehot, axis=-1)
    return jnp.mean(lse - gold)
