"""SSM and hybrid (Zamba2-style) language models.

``family == 'ssm'``    : pure Mamba2 stack (mamba2-130m).
``family == 'hybrid'`` : Mamba2 backbone with a SHARED attention+MLP block
applied after every ``cfg.attn_every`` SSM layers (Zamba2's weight-shared
global block, arXiv:2411.15242).  The shared block's KV cache is per
*application site*, not per weight copy.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from . import layers as L
from .ssm import SSM_CACHE_AXES, ssm_apply, ssm_cache_init, ssm_init


def _n_shared_sites(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    keys = jax.random.split(key, cfg.n_layers + 3)
    tree: Dict = {
        "embedding": L.embedding_init(keys[0], cfg),
        "final_norm": L.rmsnorm_init(cfg),
    }
    reps = []
    for li in range(cfg.n_layers):
        reps.append({
            "norm": L.rmsnorm_init(cfg),
            "ssm": ssm_init(keys[1 + li], cfg),
        })
    tree["ssm_layers"] = L.stack_annotated(reps)
    if cfg.attn_every:
        tree["shared"] = {
            "attn_norm": L.rmsnorm_init(cfg),
            "attn": L.attention_init(keys[-2], cfg),
            "mlp_norm": L.rmsnorm_init(cfg),
            "mlp": L.mlp_init(keys[-1], cfg),
        }
    params, axes = L.split_params(tree)
    axes["ssm_layers"] = jax.tree.map(
        lambda a: ("layers",) + tuple(a) if isinstance(a, tuple) else a,
        axes["ssm_layers"],
        is_leaf=lambda a: isinstance(a, tuple) or a is None,
    )
    return params, axes


def _shared_block(params, cfg: ModelConfig, x, *, positions, cache,
                  q_block=512, k_block=512):
    h = L.rmsnorm(params["attn_norm"], x, cfg.norm_eps)
    y, new_cache = L.attention_apply(
        params["attn"], cfg, h, positions=positions, cache=cache,
        q_block=q_block, k_block=k_block,
    )
    x = x + y
    h = L.rmsnorm(params["mlp_norm"], x, cfg.norm_eps)
    return x + L.mlp_apply(params["mlp"], cfg, h), new_cache


def _run(params, cfg: ModelConfig, x, *, positions, caches=None,
         q_block=512, k_block=512):
    ae = cfg.attn_every or cfg.n_layers
    groups = cfg.n_layers // ae if cfg.attn_every else 1
    new_ssm_caches = []
    new_attn_caches = []

    def ssm_step(carry, xs):
        h = carry
        lp, lc = xs
        hn = L.rmsnorm(lp["norm"], h, cfg.norm_eps)
        y, nc = ssm_apply(lp["ssm"], cfg, hn, cache=lc)
        return h + y, nc

    for g in range(groups):
        lo, hi = g * ae, min((g + 1) * ae, cfg.n_layers)
        seg = jax.tree.map(lambda p: p[lo:hi], params["ssm_layers"])
        seg_cache = (
            None if caches is None
            else jax.tree.map(lambda c: c[lo:hi], caches["ssm"])
        )
        body = (
            L.remat(ssm_step)
            if (cfg.remat and caches is None) else ssm_step
        )
        x, seg_new = lax.scan(body, x, (seg, seg_cache))
        if caches is not None:
            new_ssm_caches.append(seg_new)
        if cfg.attn_every:
            site_cache = (
                None if caches is None
                else jax.tree.map(lambda c: c[g], caches["attn"])
            )
            x, site_new = _shared_block(
                params["shared"], cfg, x, positions=positions,
                cache=site_cache, q_block=q_block, k_block=k_block,
            )
            if caches is not None:
                new_attn_caches.append(site_new)

    new_caches = None
    if caches is not None:
        new_caches = {
            "ssm": jax.tree.map(
                lambda *xs: jnp.concatenate(xs), *new_ssm_caches
            )
        }
        if cfg.attn_every:
            new_caches["attn"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_attn_caches
            )
    return x, new_caches


def forward(params, cfg: ModelConfig, tokens, *, q_block=512, k_block=512):
    x = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    positions = jnp.arange(tokens.shape[1])[None, :].astype(jnp.int32)
    x, _ = _run(params, cfg, x, positions=positions,
                q_block=q_block, k_block=k_block)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.logits(params["embedding"], cfg, x)


def loss_fn(params, cfg: ModelConfig, tokens, labels, **kw):
    return L.cross_entropy(forward(params, cfg, tokens, **kw), labels)


def cache_init(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    caches: Dict = {
        "ssm": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[ssm_cache_init(cfg, batch) for _ in range(cfg.n_layers)],
        )
    }
    if cfg.attn_every:
        sites = _n_shared_sites(cfg)
        caches["attn"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[L.attention_cache_init(cfg, batch, max_len)
              for _ in range(sites)],
        )
    return caches


def cache_axes(cfg: ModelConfig) -> Dict:
    axes: Dict = {
        "ssm": {k: ("layers",) + tuple(v) for k, v in SSM_CACHE_AXES.items()}
    }
    if cfg.attn_every:
        axes["attn"] = {
            k: ("layers",) + tuple(v) for k, v in L.CACHE_AXES.items()
        }
    return axes


def decode_step(params, cfg: ModelConfig, caches, tokens):
    x = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    if cfg.attn_every:
        pos = caches["attn"]["len"][0]  # (B,)
    else:
        pos = jnp.zeros((tokens.shape[0],), jnp.int32)
    positions = pos[:, None]
    x, new_caches = _run(params, cfg, x, positions=positions, caches=caches)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return L.logits(params["embedding"], cfg, x), new_caches


def prefill(params, cfg: ModelConfig, tokens, max_len: int):
    B, S = tokens.shape
    caches = cache_init(cfg, B, max_len)
    x = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    x, new_caches = _run(params, cfg, x, positions=positions, caches=caches)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return L.logits(params["embedding"], cfg, x), new_caches
