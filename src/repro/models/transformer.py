"""Decoder-only LM covering the dense and MoE families.

Layers are grouped into homogeneous *segments* so the layer stack runs under
``lax.scan`` (small HLO, fast multi-pod compiles even at 88 layers):

  dense arch            ->  [ (('dense',), L) ]
  kimi-style MoE        ->  [ (('dense',), first_dense), (('moe',), L-fd) ]
  llama4-style MoE      ->  [ (('dense','moe'), L//2) ]   (interleaved)

Each segment's parameters are stacked along a leading ``layers`` axis; decode
caches are stacked the same way and scanned jointly with the params.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from . import layers as L
from .moe import moe_apply, moe_init


# --------------------------------------------------------------------------
# segment plan
# --------------------------------------------------------------------------


def segment_plan(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    if cfg.family not in ("moe",):
        return [(("dense",), cfg.n_layers)]
    m = cfg.moe
    plan: List[Tuple[Tuple[str, ...], int]] = []
    rest = cfg.n_layers
    if m.first_dense:
        plan.append((("dense",), m.first_dense))
        rest -= m.first_dense
    if m.moe_every == 1:
        plan.append((("moe",), rest))
    elif m.moe_every == 2:
        assert rest % 2 == 0
        plan.append((("dense", "moe"), rest // 2))
    else:
        raise NotImplementedError(f"moe_every={m.moe_every}")
    return plan


def _layer_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 2)
    d_ff = cfg.d_ff
    if kind == "dense" and cfg.moe is not None and cfg.moe.dense_ff:
        d_ff = cfg.moe.dense_ff
    p = {
        "attn_norm": L.rmsnorm_init(cfg),
        "attn": L.attention_init(ks[0], cfg),
        "mlp_norm": L.rmsnorm_init(cfg),
    }
    if kind == "moe":
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg, d_ff=d_ff)
    return p


def _stack(trees):
    return L.stack_annotated(trees)


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, logical_axes)."""
    keys = jax.random.split(key, cfg.n_layers + 1)
    tree: Dict = {"embedding": L.embedding_init(keys[0], cfg),
                  "final_norm": L.rmsnorm_init(cfg)}
    li = 0
    for si, (pattern, count) in enumerate(segment_plan(cfg)):
        reps = []
        for _ in range(count):
            rep = {}
            for kind in pattern:
                rep[kind] = _layer_init(keys[1 + li], cfg, kind)
                li += 1
            reps.append(rep)
        tree[f"seg{si}"] = _stack(reps)
    params, axes = L.split_params(tree)
    # prepend the stacked-layers axis to every segment leaf's logical axes
    for si in range(len(segment_plan(cfg))):
        axes[f"seg{si}"] = jax.tree.map(
            lambda a: ("layers",) + tuple(a) if isinstance(a, tuple) else a,
            axes[f"seg{si}"],
            is_leaf=lambda a: isinstance(a, tuple) or a is None,
        )
    return params, axes


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _block(
    lp, cfg: ModelConfig, kind: str, x, *, positions, cache=None,
    q_block=512, k_block=512, lengths=None,
):
    h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    y, new_cache = L.attention_apply(
        lp["attn"], cfg, h,
        positions=positions, cache=cache,
        q_block=q_block, k_block=k_block, lengths=lengths,
    )
    x = x + y
    h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    if kind == "moe":
        x = x + moe_apply(lp["moe"], cfg, h)
    else:
        x = x + L.mlp_apply(lp["mlp"], cfg, h)
    return x, new_cache


def _run_segments(
    params, cfg: ModelConfig, x, *, positions, caches=None,
    q_block=512, k_block=512, lengths=None,
):
    """caches: same segment structure, stacked; returns (x, new_caches)."""
    new_caches: Dict = {}
    for si, (pattern, count) in enumerate(segment_plan(cfg)):
        seg = params[f"seg{si}"]
        seg_cache = None if caches is None else caches[f"seg{si}"]

        def step(carry, xs, pattern=pattern):
            h = carry
            lp, lc = xs
            ncs = {}
            for kind in pattern:
                c = None if lc is None else lc[kind]
                h, nc = _block(
                    lp[kind], cfg, kind, h,
                    positions=positions, cache=c,
                    q_block=q_block, k_block=k_block, lengths=lengths,
                )
                if nc is not None:
                    ncs[kind] = nc
            return h, (ncs if ncs else None)

        if cfg.remat and caches is None:
            step = L.remat(step)
        xs = (seg, seg_cache)
        x, seg_new_cache = lax.scan(step, x, xs)
        new_caches[f"seg{si}"] = seg_new_cache
    return x, (new_caches if caches is not None else None)


def forward(params, cfg: ModelConfig, tokens, *, q_block=512, k_block=512):
    """Training/prefill forward without cache: tokens (B, S) -> logits."""
    x = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    positions = jnp.arange(tokens.shape[1])[None, :].astype(jnp.int32)
    x, _ = _run_segments(
        params, cfg, x, positions=positions,
        q_block=q_block, k_block=k_block,
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.logits(params["embedding"], cfg, x)


def loss_fn(params, cfg: ModelConfig, tokens, labels, **kw):
    lg = forward(params, cfg, tokens, **kw)
    return L.cross_entropy(lg, labels)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def cache_init(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    def layer_cache():
        return L.attention_cache_init(cfg, batch, max_len)

    caches: Dict = {}
    for si, (pattern, count) in enumerate(segment_plan(cfg)):
        reps = []
        for _ in range(count):
            reps.append({kind: layer_cache() for kind in pattern})
        caches[f"seg{si}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    return caches


def cache_axes(cfg: ModelConfig) -> Dict:
    """Logical axes tree matching cache_init's structure."""
    def one():
        return {k: ("layers",) + tuple(v) for k, v in L.CACHE_AXES.items()}

    axes: Dict = {}
    for si, (pattern, _) in enumerate(segment_plan(cfg)):
        axes[f"seg{si}"] = {kind: one() for kind in pattern}
    return axes


def decode_step(params, cfg: ModelConfig, caches, tokens):
    """One-token decode: tokens (B, 1); caches hold the context."""
    x = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    # current position per sequence = cache length (same for every layer)
    pos = _first_cache_len(caches)
    positions = pos[:, None]
    x, new_caches = _run_segments(
        params, cfg, x, positions=positions, caches=caches
    )
    # serving needs only the next-token distribution: unembed the last
    # position (a full 32k x 152k-vocab prefill logit tensor would dwarf
    # the KV cache itself)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return L.logits(params["embedding"], cfg, x), new_caches


def _first_cache_len(caches) -> jax.Array:
    for seg in caches.values():
        def find(t):
            if isinstance(t, dict):
                if "len" in t:
                    return t["len"]
                for v in t.values():
                    r = find(v)
                    if r is not None:
                        return r
            return None
        r = find(seg)
        if r is not None:
            return r[0]  # strip the stacked-layers axis
    raise ValueError("no attention cache found")


def prefill(params, cfg: ModelConfig, tokens, max_len: int, lengths=None):
    """Prefill: forward over the prompt, building the KV caches.

    ``lengths`` (B,) declares right-padded prompts: positions past each
    row's true length are excluded from attention, the caches start at
    the true lengths, and the returned logits come from each row's last
    *real* position — so a short prompt batched with longer ones decodes
    identically to running it solo.
    """
    B, S = tokens.shape
    caches = cache_init(cfg, B, max_len)
    x = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    x, new_caches = _run_segments(
        params, cfg, x, positions=positions, caches=caches, lengths=lengths
    )
    # serving needs only the next-token distribution: unembed the last
    # position (a full 32k x 152k-vocab prefill logit tensor would dwarf
    # the KV cache itself)
    if lengths is None:
        x_last = x[:, -1:]
    else:
        idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, S - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    x = L.rmsnorm(params["final_norm"], x_last, cfg.norm_eps)
    return L.logits(params["embedding"], cfg, x), new_caches
