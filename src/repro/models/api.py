"""Uniform model API over the six families.

Everything downstream (launcher, dry-run, benchmarks, tests) talks to models
through this adapter:

    api = get_api(cfg)
    params, axes = api.init(cfg, key)
    loss = api.loss(params, cfg, batch)            # batch: dict of arrays
    logits, caches = api.prefill(params, cfg, batch, max_len)
    logits, caches = api.decode_step(params, cfg, caches, tokens)

``batch_spec`` defines the exact input tensors for every (family x shape
kind), which is also what ``launch.dryrun.input_specs`` materializes as
ShapeDtypeStructs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, hybrid, transformer, vlm

N_PATCHES = 256  # VLM stub: patches per image sequence prefix


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    forward: Callable        # (params, cfg, batch) -> logits
    loss: Callable           # (params, cfg, batch) -> scalar
    prefill: Callable        # (params, cfg, batch, max_len) -> (logits, caches)
    decode_step: Callable    # (params, cfg, caches, tokens) -> (logits, caches)
    cache_init: Callable     # (cfg, batch, max_len) -> caches
    cache_axes: Callable     # (cfg) -> logical axes tree


def _lm_api() -> ModelAPI:
    return ModelAPI(
        init=transformer.init,
        forward=lambda p, c, b, **kw: transformer.forward(
            p, c, b["tokens"], **kw
        ),
        loss=lambda p, c, b, **kw: transformer.loss_fn(
            p, c, b["tokens"], b["labels"], **kw
        ),
        prefill=lambda p, c, b, max_len, **kw: transformer.prefill(
            p, c, b["tokens"], max_len, lengths=b.get("lengths")
        ),
        decode_step=transformer.decode_step,
        cache_init=transformer.cache_init,
        cache_axes=transformer.cache_axes,
    )


def _hybrid_prefill(p, c, b, max_len):
    if b.get("lengths") is not None:
        # SSM recurrences fold every input token into the state — a pad
        # token pollutes it no matter what the attention layers mask, so
        # right-padded batching is attention-family only.
        raise NotImplementedError(
            "lengths-masked prefill is not supported for ssm/hybrid "
            "families; serve them with per-request (batch-1) prefill"
        )
    return hybrid.prefill(p, c, b["tokens"], max_len)


def _hybrid_api() -> ModelAPI:
    return ModelAPI(
        init=hybrid.init,
        forward=lambda p, c, b, **kw: hybrid.forward(p, c, b["tokens"], **kw),
        loss=lambda p, c, b, **kw: hybrid.loss_fn(
            p, c, b["tokens"], b["labels"], **kw
        ),
        prefill=lambda p, c, b, max_len, **kw: _hybrid_prefill(
            p, c, b, max_len
        ),
        decode_step=hybrid.decode_step,
        cache_init=hybrid.cache_init,
        cache_axes=hybrid.cache_axes,
    )


def _encdec_api() -> ModelAPI:
    return ModelAPI(
        init=encdec.init,
        forward=lambda p, c, b, **kw: encdec.forward(
            p, c, b["frames"], b["tokens"], **kw
        ),
        loss=lambda p, c, b, **kw: encdec.loss_fn(
            p, c, b["frames"], b["tokens"], b["labels"], **kw
        ),
        prefill=lambda p, c, b, max_len, **kw: encdec.prefill(
            p, c, b["frames"], b["tokens"], max_len
        ),
        decode_step=encdec.decode_step,
        cache_init=lambda c, batch, max_len: encdec.cache_init(
            c, batch, max_len, enc_len=max_len
        ),
        cache_axes=encdec.cache_axes,
    )


def _vlm_api() -> ModelAPI:
    return ModelAPI(
        init=vlm.init,
        forward=lambda p, c, b, **kw: vlm.forward(
            p, c, b["tokens"], b["patches"], **kw
        ),
        loss=lambda p, c, b, **kw: vlm.loss_fn(
            p, c, b["tokens"], b["patches"], b["labels"], **kw
        ),
        prefill=lambda p, c, b, max_len, **kw: vlm.prefill(
            p, c, b["tokens"], b["patches"], max_len
        ),
        decode_step=vlm.decode_step,
        cache_init=vlm.cache_init,
        cache_axes=vlm.cache_axes,
    )


_APIS = {
    "dense": _lm_api,
    "moe": _lm_api,
    "ssm": _hybrid_api,
    "hybrid": _hybrid_api,
    "encdec": _encdec_api,
    "vlm": _vlm_api,
}


def get_api(cfg: ModelConfig) -> ModelAPI:
    return _APIS[cfg.family]()


# ---------------------------------------------------------------------------
# input specifications per (family x shape kind)
# ---------------------------------------------------------------------------


def batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Tuple]:
    """name -> (shape, dtype) for the *step inputs* of this cell.

    train/prefill: full-sequence inputs.  decode: a single new token — the
    KV/state caches are separate step inputs (see dryrun.input_specs).
    Sequence-length budget S is split per family:
      encdec: S/2 encoder frames + S/2 decoder tokens
      vlm:    N_PATCHES image patches + (S - N_PATCHES) text tokens
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": ((B, 1), i32)}
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        spec = {"tokens": ((B, S), i32)}
    elif cfg.family == "encdec":
        spec = {
            "frames": ((B, S // 2, cfg.d_model), cfg.param_dtype),
            "tokens": ((B, S // 2), i32),
        }
    elif cfg.family == "vlm":
        spec = {
            "patches": ((B, N_PATCHES, vlm.VIT_DIM), cfg.param_dtype),
            "tokens": ((B, S - N_PATCHES), i32),
        }
    else:
        raise KeyError(cfg.family)
    if shape.kind == "train":
        spec["labels"] = (spec["tokens"][0], i32)
    return spec
