"""InternVL2-style VLM: ViT frontend STUB + LM backbone.

Per the assignment, the vision tower is not modelled: ``input_specs``
provides precomputed patch embeddings (B, n_patch, vit_dim).  This module
owns only the MLP projector (vit_dim -> d_model) and delegates the language
backbone to ``transformer``.  The image patches form a non-causal-irrelevant
prefix of the sequence (standard early-fusion decoding).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import transformer as T

VIT_DIM = 1024  # InternViT-300M hidden size (stubbed frontend)


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    k1, k2 = jax.random.split(key)
    lm_params, lm_axes = T.init(cfg, k1)
    proj = {
        "w": L._init(k2, (VIT_DIM, cfg.d_model), (None, "embed"),
                     cfg.param_dtype),
        "b": L._zeros((cfg.d_model,), ("embed",), cfg.param_dtype),
    }
    pp, pa = L.split_params(proj)
    lm_params["projector"] = pp
    lm_axes["projector"] = pa
    return lm_params, lm_axes


def _project(params, patches):
    return (
        jnp.dot(patches, params["projector"]["w"],
                preferred_element_type=L.F32)
        + params["projector"]["b"]
    )


def forward(params, cfg: ModelConfig, tokens, patches,
            q_block=512, k_block=512):
    """tokens (B, S_text), patches (B, n_patch, VIT_DIM) -> logits on text."""
    B, S_text = tokens.shape
    img = _project(params, patches).astype(cfg.param_dtype)
    txt = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    x = jnp.concatenate([img, txt], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    x, _ = T._run_segments(
        params, cfg, x, positions=positions,
        q_block=q_block, k_block=k_block,
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits_ = L.logits(params["embedding"], cfg, x)
    return logits_[:, -S_text:]  # predictions over the text span


def loss_fn(params, cfg: ModelConfig, tokens, patches, labels, **kw):
    return L.cross_entropy(
        forward(params, cfg, tokens, patches, **kw), labels
    )


def prefill(params, cfg: ModelConfig, tokens, patches, max_len: int):
    B, S_text = tokens.shape
    img = _project(params, patches).astype(cfg.param_dtype)
    txt = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    x = jnp.concatenate([img, txt], axis=1)
    S = x.shape[1]
    caches = T.cache_init(cfg, B, max_len)
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    x, new_caches = T._run_segments(
        params, cfg, x, positions=positions, caches=caches
    )
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return L.logits(params["embedding"], cfg, x), new_caches


decode_step = T.decode_step  # identical once the cache holds the image prefix
cache_init = T.cache_init
cache_axes = T.cache_axes
