"""Token-choice top-k Mixture-of-Experts layer (GShard/Mixtral-style).

Routing uses the sort-based capacity formulation (no dense (tokens x experts
x capacity) dispatch tensor): tokens are argsorted by expert id, positions
within each expert group come from a searchsorted over the sorted ids, and
tokens beyond the per-expert capacity are dropped.  Expert FFNs are batched
einsums over a stacked (E, D, F) weight — sharding the E axis over the
``model`` (and ``data``) mesh axes gives expert parallelism; GSPMD inserts
the dispatch/combine all-to-alls.

Note (DESIGN.md §Arch-applicability): the routing itself (gather/scatter) is
outside the paper's dense-HoF formalism; the expert FFN contractions inside
are ordinary ``rnz`` contractions and follow the framework schedule.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .layers import F32, PA, _init, mlp_init, mlp_apply


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_ff, m.n_experts
    dt = cfg.param_dtype
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": _init(ks[0], (d, e), ("embed", "experts"), F32),
        "w_gate": PA(
            jax.random.normal(ks[1], (e, d, f), F32).astype(dt) * scale,
            ("experts", "embed", "mlp"),
        ),
        "w_up": PA(
            jax.random.normal(ks[2], (e, d, f), F32).astype(dt) * scale,
            ("experts", "embed", "mlp"),
        ),
        "w_down": PA(
            jax.random.normal(ks[3], (e, f, d), F32).astype(dt)
            / math.sqrt(f),
            ("experts", "mlp", "embed"),
        ),
    }
    if m.shared_expert_ff:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=m.shared_expert_ff)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_apply(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    m = cfg.moe
    B, S, D = x.shape
    N = B * S
    E, K = m.n_experts, m.top_k
    C = capacity(cfg, N)
    xf = x.reshape(N, D)

    router_logits = jnp.dot(
        xf.astype(F32), params["router"], preferred_element_type=F32
    )  # (N, E)
    gate_vals, expert_idx = lax.top_k(router_logits, K)  # (N, K)
    gate_vals = jax.nn.softmax(gate_vals, axis=-1)

    flat_expert = expert_idx.reshape(-1)  # (N*K,)
    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[sort_idx]
    group_start = jnp.searchsorted(
        sorted_expert, jnp.arange(E), side="left"
    )
    pos_in_group = jnp.arange(N * K) - group_start[sorted_expert]
    kept = pos_in_group < C
    slot = jnp.where(kept, sorted_expert * C + pos_in_group, E * C)
    token = sort_idx // K

    dispatched = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xf[token])
    h = dispatched[: E * C].reshape(E, C, D)

    # §Perf knob: pin the dispatched tokens to the expert-parallel layout so
    # GSPMD lowers dispatch/combine to all-to-alls along the expert axis
    # instead of all-gathering the token buffer (EXPERIMENTS.md §Perf).
    import os
    if os.environ.get("REPRO_MOE_CONSTRAINT") == "1":
        from jax.sharding import PartitionSpec as P

        h = jax.lax.with_sharding_constraint(h, P("model", None, None))

    if os.environ.get("REPRO_MOE_GROUPED") == "1":
        # §Perf knob: route the three expert FFN contractions through the
        # searched ragged grouped-GEMM kernel (ops.grouped_dense) — one
        # group-offset Pallas dispatch per contraction instead of a
        # batched einsum.  The capacity layout makes the groups uniform
        # ((C,) * E), so numerics match the einsum path exactly; the same
        # entry point also serves genuinely ragged dispatch.
        from .. import ops

        F = params["w_gate"].shape[-1]
        hf = h.reshape(E * C, D)
        sizes = (C,) * E
        g = ops.grouped_dense(
            hf, params["w_gate"], sizes, out_dtype=F32
        ).reshape(E, C, F)
        u = ops.grouped_dense(
            hf, params["w_up"], sizes, out_dtype=F32
        ).reshape(E, C, F)
        act = (jax.nn.silu(g) * u).astype(x.dtype)
        out_e = ops.grouped_dense(
            act.reshape(E * C, F), params["w_down"], sizes, out_dtype=F32
        ).reshape(E, C, D).astype(x.dtype)
    else:
        g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"],
                       preferred_element_type=F32)
        u = jnp.einsum("ecd,edf->ecf", h, params["w_up"],
                       preferred_element_type=F32)
        act = (jax.nn.silu(g) * u).astype(x.dtype)
        out_e = jnp.einsum("ecf,efd->ecd", act, params["w_down"],
                           preferred_element_type=F32).astype(x.dtype)

    padded = jnp.concatenate(
        [out_e.reshape(E * C, D), jnp.zeros((1, D), x.dtype)], axis=0
    )
    contrib = padded[slot] * gate_vals.reshape(-1)[sort_idx][:, None].astype(
        x.dtype
    )
    out = jnp.zeros((N, D), x.dtype).at[token].add(contrib)

    if "shared" in params:
        out = out + mlp_apply(
            params["shared"], cfg, xf.reshape(B, S, D)
        ).reshape(N, D)
    return out.reshape(B, S, D)


def load_balance_loss(cfg: ModelConfig, router_logits, expert_idx) -> jax.Array:
    """Switch-style auxiliary loss: mean_prob * mean_assignment per expert."""
    E = cfg.moe.n_experts
    probs = jax.nn.softmax(router_logits.astype(F32), axis=-1)
    me = probs.mean(axis=0)
    one_hot = jax.nn.one_hot(expert_idx[:, 0], E, dtype=F32)
    fe = one_hot.mean(axis=0)
    return E * jnp.sum(me * fe)
