"""Mamba2 (SSD — state-space duality) blocks, arXiv:2405.21060.

Training path is the chunked SSD algorithm: the sequence is ``subdiv``-ed
into chunks; intra-chunk terms are dense contractions (which DO route through
the paper's framework formalism — they are rnz contractions with a decay
zipper), and inter-chunk terms ride a ``lax.scan`` over chunk states.  The
data-dependent recurrence itself is outside the paper's static-reducer
``rnz`` (see DESIGN.md §Arch-applicability).

Decode path is the constant-memory recurrent step on (B, H, P, N) state.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .layers import F32, PA, _init, _ones, _zeros

NEG_INF = -1e30


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, n_heads, conv_dim


def ssm_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_dim = _dims(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * s.d_state + H  # z, x, B, C, dt
    p = {
        "in_proj": _init(ks[0], (d, in_dim), ("embed", "mlp"), dt),
        "conv_w": PA(
            jax.random.normal(ks[1], (s.d_conv, conv_dim), F32).astype(dt)
            / math.sqrt(s.d_conv),
            (None, "mlp"),
        ),
        "conv_b": _zeros((conv_dim,), ("mlp",), dt),
        "A_log": PA(
            jnp.log(jnp.linspace(1.0, 16.0, H, dtype=F32)), ("heads",)
        ),
        "D": _ones((H,), ("heads",), F32),
        "dt_bias": _zeros((H,), ("heads",), F32),
        "norm_scale": _ones((d_inner,), ("mlp",), F32),
        "out_proj": _init(ks[2], (d_inner, d), ("mlp", "embed"), dt),
    }
    return p


def _segsum(x):
    """x: (..., l) log-decays -> (..., l, l) lower-triangular segment sums."""
    l = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    seg = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, seg, NEG_INF)


def ssd_chunked(x, A, B, C, chunk: int, initial_state=None):
    """SSD scan: x (b,s,h,p), A (b,s,h) log-decay, B/C (b,s,n).

    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s_len, h, p = x.shape
    n = B.shape[-1]
    chunk = math.gcd(s_len, min(chunk, s_len))
    nc = s_len // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    Ah = A.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    A_cum = jnp.cumsum(Ah, axis=-1)

    L = jnp.exp(_segsum(Ah))  # (b,h,c,l,l)
    y_diag = jnp.einsum(
        "bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc.astype(F32)
    )

    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)  # (b,h,c,l)
    states = jnp.einsum(
        "bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc.astype(F32)
    )  # per-chunk contribution to the carried state

    chunk_decay = jnp.exp(A_cum[..., -1])  # (b,h,c)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), F32)

    def step(carry, inp):
        dec, st = inp  # (b,h), (b,h,p,n)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state BEFORE this chunk

    (final_state, prev_states) = lax.scan(
        step,
        initial_state.astype(F32),
        (chunk_decay.transpose(2, 0, 1), states.transpose(1, 0, 2, 3, 4)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)

    state_decay = jnp.exp(A_cum)  # (b,h,c,l)
    y_off = jnp.einsum(
        "bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(b, s_len, h, p)
    return y.astype(x.dtype), final_state


def _causal_conv(w, bias, x):
    """Depthwise causal conv: x (B, S, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :]
        for i in range(k)
    )
    return out + bias[None, None, :]


def _split_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * s.d_state], axis=-1
    )
    return z, xbc, dt_raw


def ssm_apply(
    params, cfg: ModelConfig, x: jax.Array,
    cache: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, D) -> (B, S, D); cache = {'conv', 'state'} for decode."""
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    B_, S_, D_ = x.shape
    zxbcdt = jnp.dot(
        x, params["in_proj"], preferred_element_type=F32
    ).astype(x.dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)

    new_cache = None
    if cache is None or S_ > 1:
        conv_out = jax.nn.silu(
            _causal_conv(params["conv_w"], params["conv_b"], xbc).astype(F32)
        ).astype(x.dtype)
        if cache is not None:  # prefill: save tails
            new_conv = xbc[:, -(s.d_conv - 1):, :]
    else:
        window = jnp.concatenate([cache["conv"], xbc], axis=1)
        conv_out = jax.nn.silu(
            (
                jnp.einsum("kc,bkc->bc", params["conv_w"], window)
                + params["conv_b"]
            ).astype(F32)
        ).astype(x.dtype)[:, None, :]
        new_conv = window[:, 1:, :]

    xs, Bv, Cv = jnp.split(
        conv_out, [d_inner, d_inner + s.d_state], axis=-1
    )
    xs = xs.reshape(B_, S_, H, s.headdim)
    dt = jax.nn.softplus(dt_raw.astype(F32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])  # (H,)

    if cache is None or S_ > 1:
        init_state = None
        y, final_state = ssd_chunked(
            xs * dt[..., None].astype(x.dtype),
            dt * A,
            Bv.astype(F32), Cv.astype(F32),
            chunk=s.chunk,
            initial_state=init_state,
        )
        if cache is not None:
            new_cache = {"conv": new_conv, "state": final_state}
    else:
        dA = jnp.exp(dt[:, 0] * A)  # (B,H)
        xdt = xs[:, 0] * dt[:, 0, :, None]  # (B,H,P)
        state = (
            cache["state"] * dA[..., None, None]
            + xdt[..., None] * Bv[:, 0, None, None, :].astype(F32)
        )
        y = jnp.einsum("bhpn,bn->bhp", state, Cv[:, 0].astype(F32))
        y = y[:, None].astype(x.dtype)
        new_cache = {"conv": new_conv, "state": state}

    y = y + xs * params["D"][None, None, :, None]
    y = y.reshape(B_, S_, d_inner)
    # gated RMSNorm (mamba2)
    g = y.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"]
    out = jnp.dot(
        g.astype(x.dtype), params["out_proj"], preferred_element_type=F32
    ).astype(x.dtype)
    return out, new_cache


def ssm_cache_init(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), cfg.param_dtype),
        "state": jnp.zeros((batch, H, s.headdim, s.d_state), F32),
    }


SSM_CACHE_AXES = {
    "conv": ("batch", None, "mlp"),
    "state": ("batch", "heads", None, None),
}
