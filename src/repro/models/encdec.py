"""Whisper-style encoder-decoder (audio backbone; conv frontend is a STUB —
``input_specs`` feeds precomputed frame embeddings, per the assignment).

LayerNorm + GELU MLP + sinusoidal positions (no rope), cross-attention from
decoder to encoder output.  Decode caches both the self-attention KV and the
per-layer cross-attention KV (computed once at prefill).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from . import layers as L
from .layers import F32


def sinusoid(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=F32)[:, None]
    i = jnp.arange(dim // 2, dtype=F32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": L.layernorm_init(cfg),
        "attn": L.attention_init(ks[0], cfg),
        "mlp_norm": L.layernorm_init(cfg),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def _dec_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "self_norm": L.layernorm_init(cfg),
        "self_attn": L.attention_init(ks[0], cfg),
        "cross_norm": L.layernorm_init(cfg),
        "cross_attn": L.attention_init(ks[1], cfg),
        "mlp_norm": L.layernorm_init(cfg),
        "mlp": L.mlp_init(ks[2], cfg),
    }


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    n_enc = cfg.enc_layers
    keys = jax.random.split(key, n_enc + cfg.n_layers + 2)
    tree: Dict = {
        "embedding": L.embedding_init(keys[0], cfg),
        "enc_final_norm": L.layernorm_init(cfg),
        "dec_final_norm": L.layernorm_init(cfg),
        "enc_layers": L.stack_annotated(
            [_enc_layer_init(keys[1 + i], cfg) for i in range(n_enc)]
        ),
        "dec_layers": L.stack_annotated(
            [_dec_layer_init(keys[1 + n_enc + i], cfg)
             for i in range(cfg.n_layers)]
        ),
    }
    params, axes = L.split_params(tree)
    for k in ("enc_layers", "dec_layers"):
        axes[k] = jax.tree.map(
            lambda a: ("layers",) + tuple(a) if isinstance(a, tuple) else a,
            axes[k],
            is_leaf=lambda a: isinstance(a, tuple) or a is None,
        )
    return params, axes


def encode(params, cfg: ModelConfig, frames: jax.Array,
           q_block=512, k_block=512) -> jax.Array:
    """frames: (B, S_enc, D) precomputed frame embeddings (frontend stub)."""
    B, S, D = frames.shape
    x = (frames + sinusoid(S, D)[None]).astype(cfg.param_dtype)
    positions = jnp.arange(S)[None, :]

    def step(h, lp):
        z = L.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        y, _ = L.attention_apply(
            lp["attn"], cfg, z, positions=positions, causal=False,
            q_block=q_block, k_block=k_block,
        )
        h = h + y
        z = L.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        return h + L.mlp_apply(lp["mlp"], cfg, z), None

    x, _ = lax.scan(step, x, params["enc_layers"])
    return L.layernorm(params["enc_final_norm"], x, cfg.norm_eps)


def _cross_kv(lp, cfg: ModelConfig, enc_out):
    B, T, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = jnp.dot(enc_out, lp["wk"], preferred_element_type=F32).astype(
        enc_out.dtype).reshape(B, T, kv, hd)
    v = jnp.dot(enc_out, lp["wv"], preferred_element_type=F32).astype(
        enc_out.dtype).reshape(B, T, kv, hd)
    if cfg.qkv_bias:
        k = k + lp["bk"].reshape(kv, hd)
        v = v + lp["bv"].reshape(kv, hd)
    return k, v


def _cross_apply(lp, cfg: ModelConfig, x, k, v):
    B, S, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = jnp.dot(x, lp["wq"], preferred_element_type=F32).astype(
        x.dtype).reshape(B, S, h, hd)
    if cfg.qkv_bias:
        q = q + lp["bq"].reshape(h, hd)
    y = L.blockwise_attention(q, k, v, causal=False)
    return jnp.dot(
        y.reshape(B, S, -1), lp["wo"], preferred_element_type=F32
    ).astype(x.dtype)


def _decoder(params, cfg: ModelConfig, tokens, enc_out=None, caches=None,
             positions=None, q_block=512, k_block=512, last_only=False):
    B, S = tokens.shape
    x = L.embed(params["embedding"], tokens).astype(cfg.param_dtype)
    if positions is None:
        positions = jnp.arange(S)[None, :]
        x = x + sinusoid(S, cfg.d_model)[None].astype(x.dtype)
    else:
        # per-sequence decode positions, computed directly (no table)
        d = cfg.d_model
        i = jnp.arange(d // 2, dtype=F32)[None, :]
        ang = positions.astype(F32)[..., None] / jnp.power(
            10_000.0, 2 * i[None] / d
        )
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pe.astype(x.dtype)

    def step(h, xs):
        lp, lc = xs
        z = L.layernorm(lp["self_norm"], h, cfg.norm_eps)
        y, new_self = L.attention_apply(
            lp["self_attn"], cfg, z, positions=positions,
            cache=None if lc is None else lc["self"],
            q_block=q_block, k_block=k_block,
        )
        h = h + y
        z = L.layernorm(lp["cross_norm"], h, cfg.norm_eps)
        if enc_out is not None:  # train/prefill: compute (and cache) cross KV
            ck, cv = _cross_kv(lp["cross_attn"], cfg, enc_out)
        else:  # decode: reuse the prefill-cached cross KV
            ck, cv = lc["cross_k"], lc["cross_v"]
        h = h + _cross_apply(lp["cross_attn"], cfg, z, ck, cv)
        z = L.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + L.mlp_apply(lp["mlp"], cfg, z)
        nc = None
        if lc is not None:
            nc = {"self": new_self, "cross_k": ck, "cross_v": cv}
        return h, nc

    body = L.remat(step) if (cfg.remat and caches is None) else step
    x, new_caches = lax.scan(body, x, (params["dec_layers"], caches))
    if last_only:  # serving: only the next-token distribution is needed
        x = x[:, -1:]
    x = L.layernorm(params["dec_final_norm"], x, cfg.norm_eps)
    return L.logits(params["embedding"], cfg, x), new_caches


def forward(params, cfg: ModelConfig, frames, tokens,
            q_block=512, k_block=512):
    enc_out = encode(params, cfg, frames, q_block, k_block)
    logits_, _ = _decoder(
        params, cfg, tokens, enc_out=enc_out,
        q_block=q_block, k_block=k_block,
    )
    return logits_


def loss_fn(params, cfg: ModelConfig, frames, tokens, labels, **kw):
    return L.cross_entropy(forward(params, cfg, frames, tokens, **kw), labels)


def cache_init(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    def one():
        return {
            "self": L.attention_cache_init(cfg, batch, max_len),
            "cross_k": jnp.zeros(
                (batch, enc_len, cfg.n_kv_heads, cfg.hd), cfg.param_dtype
            ),
            "cross_v": jnp.zeros(
                (batch, enc_len, cfg.n_kv_heads, cfg.hd), cfg.param_dtype
            ),
        }

    return jax.tree.map(
        lambda *xs: jnp.stack(xs), *[one() for _ in range(cfg.n_layers)]
    )


def cache_axes(cfg: ModelConfig) -> Dict:
    return {
        "self": {k: ("layers",) + tuple(v) for k, v in L.CACHE_AXES.items()},
        "cross_k": ("layers", "batch", "seq_kv", "kv", None),
        "cross_v": ("layers", "batch", "seq_kv", "kv", None),
    }


def prefill(params, cfg: ModelConfig, frames, tokens, max_len: int):
    B, S = tokens.shape
    enc_out = encode(params, cfg, frames)
    caches = cache_init(cfg, B, max_len, frames.shape[1])
    # fill cross KV by running the decoder once over the prompt
    logits_, new_caches = _decoder(
        params, cfg, tokens, enc_out=enc_out, caches=caches, last_only=True
    )
    return logits_, new_caches


def decode_step(params, cfg: ModelConfig, caches, tokens):
    pos = caches["self"]["len"][0]  # (B,)
    logits_, new_caches = _decoder(
        params, cfg, tokens, caches=caches, positions=pos[:, None]
    )
    return logits_, new_caches
